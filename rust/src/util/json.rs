//! Minimal JSON parser and serializer.
//!
//! Covers the subset this project exchanges: objects, arrays, strings
//! (with standard escapes), f64 numbers, booleans and null.  Input is
//! UTF-8; numbers round-trip through f64 (integers up to 2^53 exact —
//! all values this repo serializes are far below that).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::Result;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow::anyhow!("missing key {key:?}")),
            _ => anyhow::bail!("expected object while reading key {key:?}"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => anyhow::bail!("expected number, found {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            anyhow::bail!("expected non-negative integer, found {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => anyhow::bail!("expected string, found {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => anyhow::bail!("expected bool, found {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => anyhow::bail!("expected array, found {self:?}"),
        }
    }

    // -- serialization ------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, e)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    e.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building objects.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Value {
    Value::Num(x)
}

pub fn str_v(s: &str) -> Value {
    Value::Str(s.to_string())
}

pub fn arr_f64(xs: &[f64]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            anyhow::bail!("expected {:?} at byte {}, found {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => anyhow::bail!("expected ',' or '}}' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => anyhow::bail!("expected ',' or ']' at byte {}, found {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                anyhow::bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // BMP only (sufficient here; surrogate pairs unsupported)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => anyhow::bail!("bad escape \\{} at byte {}", e as char, self.i),
                    }
                }
                c => {
                    // collect the full UTF-8 sequence
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            anyhow::bail!("truncated UTF-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        let x: f64 = text.parse().map_err(|e| anyhow::anyhow!("bad number {text:?}: {e}"))?;
        Ok(Value::Num(x))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let v = Value::parse(
            r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\n\"there\""}, "t": true, "n": null}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "hi\n\"there\"");
        assert!(v.get("t").unwrap().as_bool().unwrap());
        assert_eq!(*v.get("n").unwrap(), Value::Null);
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2,3],"f":0.5,"s":"x\ty","neg":-7}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(num(42.0).to_string(), "42");
        assert_eq!(num(0.5).to_string(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("hello").is_err());
        assert!(Value::parse(r#"{"a":1} extra"#).is_err());
        assert!(Value::parse("").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Value::parse(r#""café λ""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café λ");
        let round = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, round);
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Value::parse(r#"{"x": "s"}"#).unwrap();
        assert!(v.get("x").unwrap().as_f64().is_err());
        assert!(v.get("y").is_err());
        assert!(Value::parse("1.5").unwrap().as_usize().is_err());
        assert_eq!(Value::parse("17").unwrap().as_usize().unwrap(), 17);
    }
}
