//! Declarative command-line flag parsing (replacement for the `clap`
//! derive API, which is unavailable in the offline build image).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! subcommands, typed accessors with defaults, and `--help` generation.

use std::collections::BTreeMap;

use crate::Result;

/// Parsed arguments: a subcommand plus `--key value` flags.
#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    /// Flags present without a value (`--accel`).
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            let Some(name) = a.strip_prefix("--") else {
                anyhow::bail!("unexpected positional argument {a:?}");
            };
            if let Some((k, v)) = name.split_once('=') {
                out.flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                out.flags.insert(name.to_string(), it.next().unwrap());
            } else {
                out.switches.push(name.to_string());
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.str_opt(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }

    /// Comma-separated usize list.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.flags.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse().map_err(|e| anyhow::anyhow!("--{name} {p:?}: {e}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["fig13", "--threads", "4", "--accel", "--seed=9"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig13"));
        assert_eq!(a.usize_or("threads", 1).unwrap(), 4);
        assert!(a.switch("accel"));
        assert_eq!(a.u64_or("seed", 1).unwrap(), 9);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
    }

    #[test]
    fn trailing_switch_and_lists() {
        let a = parse(&["x", "--counts", "1,2,8", "--json"]);
        assert_eq!(a.usize_list_or("counts", &[]).unwrap(), vec![1, 2, 8]);
        assert!(a.switch("json"));
        assert!(!a.switch("other"));
    }

    #[test]
    fn bad_values_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_or("n", 1).is_err());
        assert!(Args::parse(["stray".to_string(), "oops".to_string()]).is_err());
    }
}
