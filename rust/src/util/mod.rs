//! In-crate utility substrates.
//!
//! The build image vendors only the `xla` crate's dependency closure, so
//! the facilities a framework normally pulls from crates.io are built
//! here from scratch:
//!
//! * [`json`] — a minimal, spec-conformant-enough JSON parser/serializer
//!   (artifact sidecars, cross-profile timing exchange, report output);
//! * [`cli`]  — a declarative flag parser for the `repro` binary and the
//!   bench harnesses.

pub mod cli;
pub mod json;
