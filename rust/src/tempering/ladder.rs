//! Inverse-temperature ladders.
//!
//! The paper's Fig-14 x-axis is "Ising model index" over the 115-replica
//! ladder, ordered cold (index 0, rarely flips) to hot (index 114, flips
//! often).  A geometric β ladder reproduces that qualitative shape; the
//! robust-selection scheme of the authors' companion paper [17] is
//! approximated by the constant-overlap geometric spacing.

/// An ordered set of inverse temperatures, coldest (largest β) first.
#[derive(Clone, Debug)]
pub struct Ladder {
    betas: Vec<f32>,
}

impl Ladder {
    /// Geometric ladder of `n` betas from `beta_cold` down to `beta_hot`
    /// (n = 1 degenerates to a single rung at `beta_cold`).
    pub fn geometric(beta_cold: f32, beta_hot: f32, n: usize) -> Self {
        assert!(n >= 1, "a ladder needs at least 1 rung");
        if n == 1 {
            return Self { betas: vec![beta_cold] };
        }
        assert!(beta_cold > beta_hot && beta_hot > 0.0, "need beta_cold > beta_hot > 0");
        let ratio = (beta_hot as f64 / beta_cold as f64).powf(1.0 / (n - 1) as f64);
        let betas = (0..n).map(|i| (beta_cold as f64 * ratio.powi(i as i32)) as f32).collect();
        Self { betas }
    }

    /// The paper's §4 configuration: 115 replicas.  β range chosen so the
    /// flip probability spans ~2%…45% on the synthetic workload, matching
    /// the qualitative range of Fig 14 (ladder mean P(flip) ≈ 0.286).
    pub fn paper_default() -> Self {
        Self::geometric(3.0, 0.5, 115)
    }

    pub fn len(&self) -> usize {
        self.betas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.betas.is_empty()
    }

    pub fn beta(&self, i: usize) -> f32 {
        self.betas[i]
    }

    pub fn betas(&self) -> &[f32] {
        &self.betas
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometric_endpoints_and_monotonicity() {
        let l = Ladder::geometric(4.0, 0.1, 16);
        assert_eq!(l.len(), 16);
        assert!((l.beta(0) - 4.0).abs() < 1e-6);
        assert!((l.beta(15) - 0.1).abs() < 1e-5);
        for i in 1..16 {
            assert!(l.beta(i) < l.beta(i - 1), "monotone decreasing");
        }
    }

    #[test]
    fn geometric_constant_ratio() {
        let l = Ladder::geometric(2.0, 0.5, 8);
        let r0 = l.beta(1) / l.beta(0);
        for i in 2..8 {
            let r = l.beta(i) / l.beta(i - 1);
            assert!((r - r0).abs() < 1e-5);
        }
    }

    #[test]
    fn paper_default_has_115_rungs() {
        assert_eq!(Ladder::paper_default().len(), 115);
    }

    #[test]
    #[should_panic(expected = "beta_cold > beta_hot")]
    fn rejects_inverted_range() {
        Ladder::geometric(0.1, 4.0, 8);
    }
}
