//! The replica-exchange engine.
//!
//! An ensemble holds one [`Sweeper`] per ladder rung.  A *round* runs a
//! batch of Metropolis sweeps on every replica at its current β, then
//! attempts exchanges between adjacent rungs (even pairs and odd pairs on
//! alternating rounds) with the standard acceptance probability
//! `min(1, exp(Δβ · ΔE))`.  Exchanges swap *states* between the rungs
//! ("the Parallel Tempering must be able to swap out the states of these
//! systems independently", §3.1), so each rung's β is fixed and the
//! per-rung flip statistics feed Fig 14 directly.

use crate::rng::Mt19937;
use crate::sweep::{SweepStats, Sweeper};

use super::ladder::Ladder;

/// Ensemble of `Send` sweepers (the CPU rungs).
pub type PtEnsemble = PtEnsembleImpl<dyn Sweeper + Send>;
/// Ensemble of thread-local sweepers (the accelerator rungs).
pub type LocalPtEnsemble = PtEnsembleImpl<dyn Sweeper>;

/// Per-rung outcome summary.
#[derive(Clone, Debug)]
pub struct ReplicaReport {
    pub beta: f32,
    pub stats: SweepStats,
    pub energy: f64,
}

/// Uniform per-replica access to a ladder's ensemble — implemented by
/// both the per-replica [`PtEnsembleImpl`] and the lane-batched
/// `BatchedPtEnsemble`, so the two share one exchange implementation (and
/// one set of detailed-balance tests).
pub trait ReplicaSet {
    fn n_replicas(&self) -> usize;
    /// Fixed inverse temperature of rung `i`.
    fn beta_of(&self, i: usize) -> f32;
    fn energy_of(&mut self, i: usize) -> f64;
    fn state_of(&mut self, i: usize) -> Vec<f32>;
    fn set_state_of(&mut self, i: usize, s: &[f32]);
}

/// One exchange pass over the adjacent pairs `(i, i+1)` for
/// `i = start, start+2, …` (`start` ∈ {0, 1} — the alternating even/odd
/// schedule): accept with the standard Metropolis probability
/// `min(1, exp(Δβ · ΔE))` and swap *states* on acceptance (each rung's β
/// is fixed).  Draws exactly one uniform per attempted pair.  Returns
/// `(attempted, accepted)`.
pub fn exchange_pass<R: ReplicaSet + ?Sized>(
    set: &mut R,
    rng: &mut Mt19937,
    start: usize,
) -> (u64, u64) {
    let n = set.n_replicas();
    let (mut attempted, mut accepted) = (0u64, 0u64);
    for i in (start..n.saturating_sub(1)).step_by(2) {
        let e_i = set.energy_of(i);
        let e_j = set.energy_of(i + 1);
        let d_beta = (set.beta_of(i) - set.beta_of(i + 1)) as f64;
        // Accept with min(1, exp(Δβ · ΔE)); Δβ > 0 (cold minus hot).
        let log_acc = d_beta * (e_i - e_j);
        attempted += 1;
        let u = rng.next_f32() as f64;
        if log_acc >= 0.0 || u < log_acc.exp() {
            accepted += 1;
            let s_i = set.state_of(i);
            let s_j = set.state_of(i + 1);
            set.set_state_of(i, &s_j);
            set.set_state_of(i + 1, &s_i);
        }
    }
    (attempted, accepted)
}

/// [`ReplicaSet`] view over a ladder plus a slice of boxed sweepers (the
/// borrow-splitting shim [`PtEnsembleImpl::exchange`] uses).
struct LadderedSweepers<'a, S: ?Sized> {
    ladder: &'a Ladder,
    replicas: &'a mut [Box<S>],
}

impl<S: Sweeper + ?Sized> ReplicaSet for LadderedSweepers<'_, S> {
    fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    fn beta_of(&self, i: usize) -> f32 {
        self.ladder.beta(i)
    }

    fn energy_of(&mut self, i: usize) -> f64 {
        self.replicas[i].energy()
    }

    fn state_of(&mut self, i: usize) -> Vec<f32> {
        self.replicas[i].state()
    }

    fn set_state_of(&mut self, i: usize, s: &[f32]) {
        self.replicas[i].set_state(s);
    }
}

/// A parallel-tempering ensemble over boxed sweepers, generic over the
/// trait-object flavour: [`PtEnsemble`] (Send sweepers — CPU rungs, can be
/// swept by the multi-threaded scheduler) or [`LocalPtEnsemble`]
/// (accelerator rungs: PJRT handles are not `Send`, one device thread).
pub struct PtEnsembleImpl<S: ?Sized> {
    ladder: Ladder,
    replicas: Vec<Box<S>>,
    stats: Vec<SweepStats>,
    swap_rng: Mt19937,
    round: u64,
    swaps_attempted: u64,
    swaps_accepted: u64,
}

impl<S: Sweeper + ?Sized> PtEnsembleImpl<S> {
    /// `replicas[i]` runs at `ladder.beta(i)`.
    pub fn new(ladder: Ladder, replicas: Vec<Box<S>>, swap_seed: u32) -> Self {
        assert_eq!(ladder.len(), replicas.len(), "one replica per rung");
        let n = replicas.len();
        Self {
            ladder,
            replicas,
            stats: vec![SweepStats::default(); n],
            swap_rng: Mt19937::new(swap_seed),
            round: 0,
            swaps_attempted: 0,
            swaps_accepted: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn ladder(&self) -> &Ladder {
        &self.ladder
    }

    /// Smallest sweep batch every replica can execute (max of the
    /// replicas' granularities; 1 for CPU rungs, `sweeps_per_call` for
    /// accelerator rungs).
    pub fn granularity(&self) -> usize {
        self.replicas.iter().map(|r| r.granularity()).max().unwrap_or(1)
    }

    /// Sweep phase of one round (no exchanges) — exposed separately so a
    /// multi-threaded coordinator can parallelise it over replicas.
    pub fn sweep_all(&mut self, n_sweeps: usize) {
        for i in 0..self.replicas.len() {
            let beta = self.ladder.beta(i);
            let s = self.replicas[i].run(n_sweeps, beta);
            self.stats[i].merge(&s);
        }
    }

    /// Exchange phase of one round: alternating even/odd adjacent pairs
    /// (the shared [`exchange_pass`] over this ensemble's replicas).
    pub fn exchange(&mut self) {
        let start = (self.round % 2) as usize;
        self.round += 1;
        let mut view =
            LadderedSweepers { ladder: &self.ladder, replicas: self.replicas.as_mut_slice() };
        let (attempted, accepted) = exchange_pass(&mut view, &mut self.swap_rng, start);
        self.swaps_attempted += attempted;
        self.swaps_accepted += accepted;
    }

    /// One full round: sweep batch + exchange.
    pub fn round(&mut self, sweeps_per_round: usize) {
        self.sweep_all(sweeps_per_round);
        self.exchange();
    }

    /// Fraction of attempted exchanges accepted.
    pub fn swap_acceptance(&self) -> f64 {
        if self.swaps_attempted == 0 {
            0.0
        } else {
            self.swaps_accepted as f64 / self.swaps_attempted as f64
        }
    }

    /// State of replica `i` in original order (tests, checkpointing).
    pub fn state_of(&mut self, i: usize) -> Vec<f32> {
        self.replicas[i].state()
    }

    /// Overwrite replica `i`'s state (checkpoint restore).
    pub fn set_state_of(&mut self, i: usize, s: &[f32]) {
        self.replicas[i].set_state(s);
    }

    /// Per-rung reports (β is the rung's fixed temperature).
    pub fn reports(&mut self) -> Vec<ReplicaReport> {
        (0..self.replicas.len())
            .map(|i| ReplicaReport {
                beta: self.ladder.beta(i),
                stats: self.stats[i],
                energy: self.replicas[i].energy(),
            })
            .collect()
    }

    /// Mutable access for the coordinator's parallel sweep phase.
    pub(crate) fn split_mut(&mut self) -> (&Ladder, &mut [Box<S>], &mut [SweepStats]) {
        (&self.ladder, &mut self.replicas, &mut self.stats)
    }

    // -- checkpoint support (bit-exact resume) ----------------------------

    /// The rung replica `i` runs on (checkpoint compatibility checks).
    pub fn kind_of(&self, i: usize) -> crate::sweep::SweepKind {
        self.replicas[i].kind()
    }

    /// True lane width of replica `i` (covers widths the legacy kind tag
    /// cannot spell — checkpoint schema-v2 compatibility checks).
    pub fn width_of(&self, i: usize) -> usize {
        self.replicas[i].width()
    }

    /// Replica `i`'s serialized RNG state (None when the rung cannot
    /// serialize its generator).
    pub fn rng_state_of(&self, i: usize) -> Option<Vec<u32>> {
        self.replicas[i].rng_state()
    }

    /// Restore replica `i`'s RNG state; `false` on mismatch/unsupported.
    pub fn set_rng_state_of(&mut self, i: usize, words: &[u32]) -> bool {
        self.replicas[i].set_rng_state(words)
    }

    /// Serialized exchange-RNG state.
    pub fn swap_rng_state(&self) -> Vec<u32> {
        self.swap_rng.state_words()
    }

    /// Restore the exchange-RNG state; `false` on a malformed payload.
    pub fn set_swap_rng_state(&mut self, words: &[u32]) -> bool {
        self.swap_rng.restore_words(words)
    }

    /// Exchange-round counter (decides the even/odd pairing parity).
    pub fn round_index(&self) -> u64 {
        self.round
    }

    /// Restore the exchange-round counter (checkpoint resume).
    pub fn set_round_index(&mut self, round: u64) {
        self.round = round;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::builder::torus_workload;
    use crate::sweep::{try_make_sweeper, SweepKind};

    fn ensemble(n: usize) -> PtEnsemble {
        let ladder = Ladder::geometric(2.0, 0.2, n);
        let replicas = (0..n)
            .map(|i| {
                let wl = torus_workload(4, 4, 8, 7, 0.3);
                try_make_sweeper(SweepKind::A2Basic, &wl.model, &wl.s0, 100 + i as u32).unwrap()
            })
            .collect();
        PtEnsemble::new(ladder, replicas, 999)
    }

    #[test]
    fn exchange_preserves_state_multiset() {
        let mut pt = ensemble(6);
        pt.sweep_all(5);
        let mut before: Vec<Vec<u32>> = (0..6)
            .map(|i| pt.replicas[i].state().iter().map(|&x| x.to_bits()).collect())
            .collect();
        pt.exchange();
        let mut after: Vec<Vec<u32>> = (0..6)
            .map(|i| pt.replicas[i].state().iter().map(|&x| x.to_bits()).collect())
            .collect();
        before.sort();
        after.sort();
        assert_eq!(before, after, "exchange must permute states, not mutate them");
    }

    #[test]
    fn hot_replicas_flip_more() {
        let mut pt = ensemble(6);
        pt.sweep_all(40);
        let reports = pt.reports();
        let cold = reports.first().unwrap().stats.flip_prob();
        let hot = reports.last().unwrap().stats.flip_prob();
        assert!(hot > cold, "hot {hot} should flip more than cold {cold}");
    }

    #[test]
    fn rounds_accumulate_stats_and_swap() {
        let mut pt = ensemble(8);
        for _ in 0..10 {
            pt.round(5);
        }
        assert!(pt.swap_acceptance() > 0.0, "dense ladder should accept some swaps");
        let reports = pt.reports();
        assert_eq!(reports.len(), 8);
        for r in &reports {
            assert_eq!(r.stats.attempts, 10 * 5 * 4 * 4 * 8);
        }
    }
}
