//! Lane-batched parallel tempering — the ladder partitioned into C-rung
//! groups, one SIMD lane per replica, with **per-group plans**.
//!
//! A [`BatchedPtEnsemble`] covers the same ladder as a [`PtEnsemble`] of
//! scalar sweepers, but sweeps it in lane groups.  Groups are
//! independent units of work, so they do not have to share a width or a
//! backend: a run may schedule an AVX2 `C.1w8` group next to an SSE2
//! `C.1` tail group (see [`GroupPlan`]).  Replica trajectories are
//! *grouping-invariant*: lane `k` of any group runs the exact scalar
//! A.2 trajectory of its replica (same per-replica seed, lane-exact
//! generator), so how the ladder is partitioned is purely a
//! performance/padding choice, never a statistical one — the
//! differential suite pins this down.
//!
//! Partitioning: a width-pinned spec produces homogeneous groups padded
//! at the tail (the historical layout).  A `width: auto` spec produces
//! full groups at the negotiated widest width plus, when a narrower
//! monomorphized width still fits the remainder, a *narrower* tail
//! group taken from the plan's fallback widths — e.g. 10 replicas on an
//! AVX2 host become `[C.1w8 × 8 replicas, C.1 × 2 replicas]` instead of
//! a second octet group with six padded lanes.
//!
//! Padded lanes burn a little compute and are excluded from every
//! report, exchange and checkpoint (lanes never interact during sweeps,
//! so the padding cannot perturb the active chains).  Exchanges stay on
//! the coordinator thread between sweep rounds, exactly as in the
//! per-replica ensemble — both run the shared [`exchange_pass`], so the
//! two engines are statistically interchangeable (and, lane for lane,
//! bit-exact under `ExpMode::Exact`).

use crate::engine::{EngineBuilder, GroupPlan, SamplerSpec, Width};
use crate::ising::QmcModel;
use crate::rng::Mt19937;
use crate::sweep::c1_replica_batch::BatchSweeper;
use crate::sweep::{ExpMode, SweepStats};
use crate::Result;

use super::ladder::Ladder;
use super::pt::{exchange_pass, ReplicaReport, ReplicaSet};

/// A parallel-tempering ensemble swept in lane-batches by C-rungs, one
/// (possibly different) resolved plan per group.
pub struct BatchedPtEnsemble {
    ladder: Ladder,
    /// The spec the ensemble was requested with (recorded in schema-v2
    /// checkpoints so resume is spec-driven).
    spec: SamplerSpec,
    /// One resolved plan per group, in ladder order.
    groups: Vec<GroupPlan>,
    /// First replica index of each group (prefix sums of `replicas`).
    offsets: Vec<usize>,
    batches: Vec<Box<dyn BatchSweeper + Send>>,
    /// Per-group β vectors (padded lanes repeat the last active β).
    lane_betas: Vec<Vec<f32>>,
    /// Per-replica accumulated stats (active replicas only).
    stats: Vec<SweepStats>,
    swap_rng: Mt19937,
    round: u64,
    swaps_attempted: u64,
    swaps_accepted: u64,
}

/// Partition `n` replicas under `spec`: homogeneous groups for a pinned
/// width; for `width: auto`, full groups at the negotiated width plus a
/// narrower tail group when one fits better (resolved through the same
/// builder, so the tail honors the backend preference and host
/// capabilities — this is where the plan's fallback chain becomes a
/// heterogeneous schedule).
pub fn plan_groups(
    spec: SamplerSpec,
    n: usize,
    layers: usize,
    exp: ExpMode,
) -> Result<Vec<GroupPlan>> {
    anyhow::ensure!(n > 0, "cannot batch an empty ladder");
    anyhow::ensure!(
        spec.rung.is_replica_batch(),
        "{} is not a replica-batch rung",
        spec.rung.label()
    );
    let plan = EngineBuilder::new(spec).layers(layers).exp(exp).plan()?;
    let w = plan.width;
    let (full, tail) = (n / w, n % w);
    let mut groups = vec![GroupPlan::new(plan.resolved(), w); full];
    if tail > 0 {
        let mut tail_group = GroupPlan::new(plan.resolved(), tail);
        if spec.width == Width::Auto {
            // Narrowest monomorphized width that still fits the tail.
            let narrower = crate::engine::builder::MONO_WIDTHS
                .iter()
                .copied()
                .filter(|&tw| tw < w && tw >= tail)
                .min();
            if let Some(tw) = narrower {
                let tail_spec = SamplerSpec { width: Width::W(tw), ..spec };
                if let Ok(tp) = EngineBuilder::new(tail_spec).layers(layers).exp(exp).plan() {
                    tail_group = GroupPlan::new(tp.resolved(), tail);
                }
            }
        }
        groups.push(tail_group);
    }
    Ok(groups)
}

impl BatchedPtEnsemble {
    /// Build a batched ensemble: replica `i` runs `models[i]` from
    /// `states[i]` at `ladder.beta(i)`, with RNG stream `seeds[i]` — the
    /// same per-replica seed convention as the scalar ensemble, so lane
    /// `i` reproduces the scalar replica `i` trajectory bit-for-bit under
    /// `ExpMode::Exact`.
    ///
    /// Takes anything that lowers onto a [`SamplerSpec`] (a legacy
    /// C-rung `SweepKind` or a `c1` spec); the group layout comes from
    /// [`plan_groups`] — *any* width the builder can instantiate works,
    /// including the portable `C.1w16` the legacy enum cannot spell.
    pub fn new(
        ladder: Ladder,
        spec: impl Into<SamplerSpec>,
        models: &[QmcModel],
        states: &[Vec<f32>],
        seeds: &[u32],
        swap_seed: u32,
        exp: ExpMode,
    ) -> Result<Self> {
        let spec = spec.into();
        anyhow::ensure!(!models.is_empty(), "cannot batch an empty ladder");
        let groups = plan_groups(spec, ladder.len(), models[0].n_layers, exp)?;
        Self::with_groups(ladder, spec, &groups, models, states, seeds, swap_seed, exp)
    }

    /// Build with an explicit (possibly heterogeneous) group layout.
    /// `groups[g].replicas` active lanes of group `g` cover the ladder in
    /// order; each group is instantiated from its own resolved plan.
    #[allow(clippy::too_many_arguments)]
    pub fn with_groups(
        ladder: Ladder,
        spec: SamplerSpec,
        groups: &[GroupPlan],
        models: &[QmcModel],
        states: &[Vec<f32>],
        seeds: &[u32],
        swap_seed: u32,
        exp: ExpMode,
    ) -> Result<Self> {
        let n = ladder.len();
        anyhow::ensure!(
            models.len() == n && states.len() == n && seeds.len() == n,
            "need one model/state/seed per ladder rung ({n}), got {}/{}/{}",
            models.len(),
            states.len(),
            seeds.len()
        );
        anyhow::ensure!(n > 0, "cannot batch an empty ladder");
        anyhow::ensure!(!groups.is_empty(), "need at least one group");
        let covered: usize = groups.iter().map(|g| g.replicas).sum();
        anyhow::ensure!(
            covered == n,
            "group layout covers {covered} replicas, ladder has {n}"
        );
        for (gi, g) in groups.iter().enumerate() {
            anyhow::ensure!(
                g.resolved.rung.is_replica_batch(),
                "group {gi}: {} is not a replica-batch rung",
                g.resolved.rung.label()
            );
            anyhow::ensure!(
                g.replicas >= 1 && g.replicas <= g.resolved.width,
                "group {gi}: {} active replicas do not fit width {}",
                g.replicas,
                g.resolved.width
            );
        }
        let mut offsets = Vec::with_capacity(groups.len());
        let mut batches = Vec::with_capacity(groups.len());
        let mut lane_betas = Vec::with_capacity(groups.len());
        let mut offset = 0usize;
        for g in groups {
            let w = g.resolved.width;
            // Pad the group with clones of its last active replica; padded
            // lanes get distinct off-ladder seeds so their (discarded)
            // streams never alias an active one.
            let last = offset + g.replicas - 1;
            let lane_idx = |k: usize| (offset + k).min(last);
            let lane_models: Vec<QmcModel> = (0..w).map(|k| models[lane_idx(k)].clone()).collect();
            let lane_states: Vec<Vec<f32>> =
                (0..w).map(|k| states[lane_idx(k)].clone()).collect();
            let lane_seeds: Vec<u32> = (0..w)
                .map(|k| {
                    if k < g.replicas {
                        seeds[offset + k]
                    } else {
                        seeds[last] ^ 0x8000_0000 ^ ((offset + k) as u32)
                    }
                })
                .collect();
            let betas: Vec<f32> = (0..w).map(|k| ladder.beta(lane_idx(k))).collect();
            batches.push(crate::engine::builder::instantiate_batch(
                g.resolved,
                &lane_models,
                &lane_states,
                &lane_seeds,
                exp,
            )?);
            lane_betas.push(betas);
            offsets.push(offset);
            offset += g.replicas;
        }
        Ok(Self {
            ladder,
            spec,
            groups: groups.to_vec(),
            offsets,
            batches,
            lane_betas,
            stats: vec![SweepStats::default(); n],
            swap_rng: Mt19937::new(swap_seed),
            round: 0,
            swaps_attempted: 0,
            swaps_accepted: 0,
        })
    }

    /// The spec the ensemble was requested with.
    pub fn spec(&self) -> SamplerSpec {
        self.spec
    }

    /// The resolved per-group plans, in ladder order.
    pub fn plans(&self) -> &[GroupPlan] {
        &self.groups
    }

    /// Joined label of the group plans (`C.1w8`, or `C.1w8+C.1` for a
    /// heterogeneous layout).
    pub fn label(&self) -> String {
        crate::engine::groups_label(&self.groups)
    }

    /// Active replicas (= ladder rungs; padding excluded).
    pub fn len(&self) -> usize {
        self.ladder.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ladder.is_empty()
    }

    /// Widest lane count across the groups.
    pub fn width(&self) -> usize {
        self.groups.iter().map(|g| g.resolved.width).max().unwrap_or(0)
    }

    /// Number of lane groups (tail possibly padded or narrower).
    pub fn n_batches(&self) -> usize {
        self.batches.len()
    }

    pub fn ladder(&self) -> &Ladder {
        &self.ladder
    }

    /// Map a global replica index onto `(group, lane)`.
    fn locate(&self, i: usize) -> (usize, usize) {
        locate(&self.offsets, i)
    }

    /// Sweep phase of one round: every group for `n_sweeps`, each lane at
    /// its rung's β.  (The coordinator parallelises this over groups via
    /// `scheduler::parallel_sweep_batches`.)
    pub fn sweep_all(&mut self, n_sweeps: usize) {
        for (g, batch) in self.batches.iter_mut().enumerate() {
            let per_lane = batch.run(n_sweeps, &self.lane_betas[g]);
            let offset = self.offsets[g];
            for (k, s) in per_lane.iter().take(self.groups[g].replicas).enumerate() {
                self.stats[offset + k].merge(s);
            }
        }
    }

    /// Exchange phase of one round — identical schedule and acceptance
    /// rule to the per-replica ensemble (the shared [`exchange_pass`]).
    pub fn exchange(&mut self) {
        let start = (self.round % 2) as usize;
        self.round += 1;
        let mut view = BatchedReplicas {
            ladder: &self.ladder,
            batches: self.batches.as_mut_slice(),
            offsets: &self.offsets,
        };
        let (attempted, accepted) = exchange_pass(&mut view, &mut self.swap_rng, start);
        self.swaps_attempted += attempted;
        self.swaps_accepted += accepted;
    }

    /// One full round: sweep batch + exchange.
    pub fn round(&mut self, sweeps_per_round: usize) {
        self.sweep_all(sweeps_per_round);
        self.exchange();
    }

    /// Fraction of attempted exchanges accepted.
    pub fn swap_acceptance(&self) -> f64 {
        if self.swaps_attempted == 0 {
            0.0
        } else {
            self.swaps_accepted as f64 / self.swaps_attempted as f64
        }
    }

    /// State of replica `i` in original order.
    pub fn state_of(&mut self, i: usize) -> Vec<f32> {
        assert!(i < self.ladder.len());
        let (g, lane) = self.locate(i);
        self.batches[g].state_of(lane)
    }

    /// Overwrite replica `i`'s state (checkpoint restore).
    pub fn set_state_of(&mut self, i: usize, s: &[f32]) {
        assert!(i < self.ladder.len());
        let (g, lane) = self.locate(i);
        self.batches[g].set_state_of(lane, s);
    }

    /// Worst incremental-field inconsistency across every batch.
    pub fn validate(&mut self) -> f64 {
        self.batches.iter_mut().map(|b| b.validate()).fold(0.0f64, f64::max)
    }

    /// Per-rung reports (active replicas, ladder-ordered).
    pub fn reports(&mut self) -> Vec<ReplicaReport> {
        (0..self.ladder.len())
            .map(|i| {
                let (g, lane) = locate(&self.offsets, i);
                ReplicaReport {
                    beta: self.ladder.beta(i),
                    stats: self.stats[i],
                    energy: self.batches[g].energy_of(lane),
                }
            })
            .collect()
    }

    // -- checkpoint support (bit-exact resume) ----------------------------

    /// Per-group serialized RNG states.
    pub fn rng_states(&self) -> Vec<Vec<u32>> {
        self.batches.iter().map(|b| b.rng_state()).collect()
    }

    /// Restore per-group RNG states; `false` on any mismatch.
    pub fn set_rng_states(&mut self, states: &[Vec<u32>]) -> bool {
        states.len() == self.batches.len()
            && self
                .batches
                .iter_mut()
                .zip(states)
                .all(|(b, words)| b.set_rng_state(words))
    }

    /// Serialized exchange-RNG state.
    pub fn swap_rng_state(&self) -> Vec<u32> {
        self.swap_rng.state_words()
    }

    /// Restore the exchange-RNG state; `false` on a malformed payload.
    pub fn set_swap_rng_state(&mut self, words: &[u32]) -> bool {
        self.swap_rng.restore_words(words)
    }

    /// Exchange-round counter (even/odd pairing parity).
    pub fn round_index(&self) -> u64 {
        self.round
    }

    /// Restore the exchange-round counter (checkpoint resume).
    pub fn set_round_index(&mut self, round: u64) {
        self.round = round;
    }

    /// Mutable access for the coordinator's parallel sweep phase:
    /// `(per-group betas, batches, per-replica stats, per-group active
    /// replica counts)`.  Stats are ladder-ordered, so splitting the
    /// stats slice by the active counts aligns it with `batches`.
    #[allow(clippy::type_complexity)]
    pub(crate) fn split_mut(
        &mut self,
    ) -> (&[Vec<f32>], &mut [Box<dyn BatchSweeper + Send>], &mut [SweepStats], Vec<usize>) {
        let actives: Vec<usize> = self.groups.iter().map(|g| g.replicas).collect();
        (&self.lane_betas, &mut self.batches, &mut self.stats, actives)
    }
}

/// `(group, lane)` of global replica `i` given per-group start offsets.
fn locate(offsets: &[usize], i: usize) -> (usize, usize) {
    let g = offsets.partition_point(|&o| o <= i) - 1;
    (g, i - offsets[g])
}

/// [`ReplicaSet`] view mapping global replica indices onto (group, lane).
struct BatchedReplicas<'a> {
    ladder: &'a Ladder,
    batches: &'a mut [Box<dyn BatchSweeper + Send>],
    offsets: &'a [usize],
}

impl ReplicaSet for BatchedReplicas<'_> {
    fn n_replicas(&self) -> usize {
        self.ladder.len()
    }

    fn beta_of(&self, i: usize) -> f32 {
        self.ladder.beta(i)
    }

    fn energy_of(&mut self, i: usize) -> f64 {
        let (g, lane) = locate(self.offsets, i);
        self.batches[g].energy_of(lane)
    }

    fn state_of(&mut self, i: usize) -> Vec<f32> {
        let (g, lane) = locate(self.offsets, i);
        self.batches[g].state_of(lane)
    }

    fn set_state_of(&mut self, i: usize, s: &[f32]) {
        let (g, lane) = locate(self.offsets, i);
        self.batches[g].set_state_of(lane, s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{BackendPref, Resolved, Rung};
    use crate::ising::builder::torus_workload;
    use crate::sweep::SweepKind;

    fn workload_parts(n: usize) -> (Vec<QmcModel>, Vec<Vec<f32>>, Vec<u32>) {
        let wl = torus_workload(4, 4, 8, 7, 0.3);
        let models = vec![wl.model.clone(); n];
        let states = vec![wl.s0.clone(); n];
        let seeds: Vec<u32> = (0..n as u32).map(|i| 100 + i).collect();
        (models, states, seeds)
    }

    fn build(n: usize, kind: SweepKind) -> BatchedPtEnsemble {
        let ladder = Ladder::geometric(2.0, 0.2, n);
        let (models, states, seeds) = workload_parts(n);
        BatchedPtEnsemble::new(ladder, kind, &models, &states, &seeds, 999, ExpMode::Fast)
            .unwrap()
    }

    #[test]
    fn padded_tail_batch_keeps_active_counts() {
        // 6 replicas at W=4 -> 2 batches, 2 padded lanes.
        let mut pt = build(6, SweepKind::C1ReplicaBatch);
        assert_eq!(pt.len(), 6);
        assert_eq!(pt.n_batches(), 2);
        pt.sweep_all(5);
        let reports = pt.reports();
        assert_eq!(reports.len(), 6);
        for r in &reports {
            assert_eq!(r.stats.attempts, 5 * 4 * 4 * 8);
        }
    }

    #[test]
    fn hot_replicas_flip_more() {
        let mut pt = build(6, SweepKind::C1ReplicaBatch);
        pt.sweep_all(40);
        let reports = pt.reports();
        let cold = reports.first().unwrap().stats.flip_prob();
        let hot = reports.last().unwrap().stats.flip_prob();
        assert!(hot > cold, "hot {hot} should flip more than cold {cold}");
    }

    #[test]
    fn exchange_preserves_state_multiset_across_batch_boundaries() {
        let mut pt = build(6, SweepKind::C1ReplicaBatch);
        pt.sweep_all(5);
        let fingerprint = |pt: &mut BatchedPtEnsemble| -> Vec<Vec<u32>> {
            (0..pt.len())
                .map(|i| pt.state_of(i).iter().map(|x| x.to_bits()).collect())
                .collect()
        };
        let mut before = fingerprint(&mut pt);
        pt.exchange();
        pt.exchange(); // cover the odd parity (incl. the 3/4 pair)
        let mut after = fingerprint(&mut pt);
        before.sort();
        after.sort();
        assert_eq!(before, after, "exchange must permute states, not mutate them");
    }

    #[test]
    fn rounds_accumulate_stats_and_swap() {
        let mut pt = build(8, SweepKind::C1ReplicaBatchW8);
        for _ in 0..10 {
            pt.round(5);
        }
        assert!(pt.swap_acceptance() > 0.0, "dense ladder should accept some swaps");
        assert!(pt.validate() < 1e-3);
    }

    #[test]
    fn rejects_non_batch_kinds_and_bad_arity() {
        let ladder = Ladder::geometric(2.0, 0.2, 4);
        let (models, states, seeds) = workload_parts(4);
        assert!(BatchedPtEnsemble::new(
            ladder.clone(),
            SweepKind::A4Full,
            &models,
            &states,
            &seeds,
            1,
            ExpMode::Fast
        )
        .is_err());
        assert!(BatchedPtEnsemble::new(
            ladder,
            SweepKind::C1ReplicaBatch,
            &models[..3],
            &states,
            &seeds,
            1,
            ExpMode::Fast
        )
        .is_err());
    }

    #[test]
    fn spec_widths_beyond_the_legacy_enum_build() {
        // The unlock of Checkpoint schema v2: a portable C.1w16 batch runs
        // through the coordinator surface the legacy enum could not spell.
        let n = 5;
        let ladder = Ladder::geometric(2.0, 0.2, n);
        let (models, states, seeds) = workload_parts(n);
        let spec = SamplerSpec::rung(Rung::C1).w(16).on(BackendPref::Portable);
        let mut pt =
            BatchedPtEnsemble::new(ladder, spec, &models, &states, &seeds, 999, ExpMode::Fast)
                .unwrap();
        assert_eq!(pt.n_batches(), 1);
        assert_eq!(pt.plans().len(), 1);
        assert_eq!(pt.plans()[0].resolved.width, 16);
        assert_eq!(pt.plans()[0].replicas, 5);
        assert_eq!(pt.label(), "C.1w16");
        pt.round(5);
        assert!(pt.validate() < 1e-3);
        assert_eq!(pt.reports().len(), 5);
    }

    #[test]
    fn heterogeneous_groups_match_homogeneous_trajectories() {
        // 10 replicas as [w8 x 8, w4 x 2] must reproduce, replica for
        // replica, the homogeneous w4 layout bit-exactly: grouping is a
        // performance choice, never a statistical one.
        let n = 10;
        let ladder = Ladder::geometric(2.0, 0.2, n);
        let (models, states, seeds) = workload_parts(n);
        let spec = SamplerSpec::rung(Rung::C1).on(BackendPref::Portable);
        let r = |w| Resolved {
            rung: Rung::C1,
            backend: crate::engine::Backend::Portable,
            width: w,
        };
        let groups = [GroupPlan::new(r(8), 8), GroupPlan::new(r(4), 2)];
        let mut het = BatchedPtEnsemble::with_groups(
            ladder.clone(),
            spec,
            &groups,
            &models,
            &states,
            &seeds,
            999,
            ExpMode::Fast,
        )
        .unwrap();
        let mut homo = BatchedPtEnsemble::new(
            ladder,
            SamplerSpec::rung(Rung::C1).w(4).on(BackendPref::Portable),
            &models,
            &states,
            &seeds,
            999,
            ExpMode::Fast,
        )
        .unwrap();
        assert_eq!(het.n_batches(), 2);
        assert_eq!(het.label(), "C.1w8+C.1");
        for _ in 0..3 {
            het.round(5);
            homo.round(5);
        }
        for i in 0..n {
            assert_eq!(het.state_of(i), homo.state_of(i), "replica {i} diverged");
        }
        let a = het.reports();
        let b = homo.reports();
        for i in 0..n {
            assert_eq!(a[i].energy.to_bits(), b[i].energy.to_bits(), "replica {i} energy");
            assert_eq!(a[i].stats.flips, b[i].stats.flips, "replica {i} flips");
        }
    }

    #[test]
    fn auto_width_partitions_with_a_narrower_tail() {
        // plan_groups under width auto: full groups at the widest
        // negotiated width, tail at the narrowest fitting width.
        let spec = SamplerSpec::rung(Rung::C1).on(BackendPref::Portable);
        // Portable pref negotiates width 4 — 10 replicas: 2 full + tail 2.
        let groups = plan_groups(spec, 10, 8, ExpMode::Fast).unwrap();
        let total: usize = groups.iter().map(|g| g.replicas).sum();
        assert_eq!(total, 10);
        assert!(groups.iter().all(|g| g.replicas <= g.resolved.width));
        // A pinned width keeps the homogeneous padded layout.
        let pinned = plan_groups(
            SamplerSpec::rung(Rung::C1).w(8).on(BackendPref::Portable),
            10,
            8,
            ExpMode::Fast,
        )
        .unwrap();
        assert_eq!(pinned.len(), 2);
        assert!(pinned.iter().all(|g| g.resolved.width == 8));
        assert_eq!(pinned[1].replicas, 2);
    }

    #[test]
    fn group_layout_validation_rejects_bad_covers() {
        let n = 6;
        let ladder = Ladder::geometric(2.0, 0.2, n);
        let (models, states, seeds) = workload_parts(n);
        let spec = SamplerSpec::rung(Rung::C1).on(BackendPref::Portable);
        let r = |w| Resolved {
            rung: Rung::C1,
            backend: crate::engine::Backend::Portable,
            width: w,
        };
        // Covers 5 of 6 replicas.
        let short = [GroupPlan::new(r(4), 4), GroupPlan::new(r(4), 1)];
        assert!(BatchedPtEnsemble::with_groups(
            ladder.clone(),
            spec,
            &short,
            &models,
            &states,
            &seeds,
            1,
            ExpMode::Fast
        )
        .is_err());
        // 5 active replicas in a width-4 group.
        let overfull = [GroupPlan::new(r(4), 5), GroupPlan::new(r(4), 1)];
        assert!(BatchedPtEnsemble::with_groups(
            ladder,
            spec,
            &overfull,
            &models,
            &states,
            &seeds,
            1,
            ExpMode::Fast
        )
        .is_err());
    }
}
