//! Lane-batched parallel tempering — the ladder grouped into C-rung
//! batches of `W` replicas, one SIMD lane per replica.
//!
//! A [`BatchedPtEnsemble`] covers the same ladder as a [`PtEnsemble`] of
//! scalar sweepers, but sweeps it `W` replicas at a time: rung `i` is
//! lane `i % W` of batch `i / W`.  The last batch is padded with clones
//! of the final replica when the ladder length is not a multiple of `W`
//! — padded lanes burn a little compute and are excluded from every
//! report, exchange and checkpoint (lanes never interact during sweeps,
//! so the padding cannot perturb the active chains).
//!
//! Exchanges stay on the coordinator thread between sweep rounds,
//! exactly as in the per-replica ensemble — both run the shared
//! [`exchange_pass`], so the two engines are statistically
//! interchangeable (and, lane for lane, bit-exact under
//! `ExpMode::Exact`; the differential suite asserts it).

use crate::ising::QmcModel;
use crate::rng::Mt19937;
use crate::sweep::c1_replica_batch::BatchSweeper;
use crate::sweep::{ExpMode, SweepKind, SweepStats};
use crate::Result;

use super::ladder::Ladder;
use super::pt::{exchange_pass, ReplicaReport, ReplicaSet};

/// A parallel-tempering ensemble swept in lane-batches by a C-rung.
pub struct BatchedPtEnsemble {
    ladder: Ladder,
    kind: SweepKind,
    width: usize,
    batches: Vec<Box<dyn BatchSweeper + Send>>,
    /// Per-batch β vectors (padded lanes repeat the last active β).
    lane_betas: Vec<Vec<f32>>,
    /// Per-replica accumulated stats (active replicas only).
    stats: Vec<SweepStats>,
    swap_rng: Mt19937,
    round: u64,
    swaps_attempted: u64,
    swaps_accepted: u64,
}

impl BatchedPtEnsemble {
    /// Build a batched ensemble: replica `i` runs `models[i]` from
    /// `states[i]` at `ladder.beta(i)`, with RNG stream `seeds[i]` — the
    /// same per-replica seed convention as the scalar ensemble, so lane
    /// `i` reproduces the scalar replica `i` trajectory bit-for-bit under
    /// `ExpMode::Exact`.
    ///
    /// Takes anything that lowers onto a [`crate::engine::SamplerSpec`]
    /// (a legacy C-rung [`SweepKind`] or a `c1` spec); the backend and
    /// effective width come from the negotiated plan.
    pub fn new(
        ladder: Ladder,
        spec: impl Into<crate::engine::SamplerSpec>,
        models: &[QmcModel],
        states: &[Vec<f32>],
        seeds: &[u32],
        swap_seed: u32,
        exp: ExpMode,
    ) -> Result<Self> {
        let spec = spec.into();
        anyhow::ensure!(
            spec.rung.is_replica_batch(),
            "{} is not a replica-batch rung",
            spec.rung.label()
        );
        let n = ladder.len();
        anyhow::ensure!(
            models.len() == n && states.len() == n && seeds.len() == n,
            "need one model/state/seed per ladder rung ({n}), got {}/{}/{}",
            models.len(),
            states.len(),
            seeds.len()
        );
        anyhow::ensure!(n > 0, "cannot batch an empty ladder");
        let plan = crate::engine::EngineBuilder::new(spec)
            .layers(models[0].n_layers)
            .exp(exp)
            .plan()?;
        let kind = plan.legacy_kind().ok_or_else(|| {
            anyhow::anyhow!(
                "the coordinator's checkpoint format spells widths 4 and 8 only (plan resolved \
                 to width {}); build the batch directly via engine::EngineBuilder::build_batch",
                plan.width
            )
        })?;
        let w = plan.width;
        let n_batches = n.div_ceil(w);
        let mut batches = Vec::with_capacity(n_batches);
        let mut lane_betas = Vec::with_capacity(n_batches);
        for b in 0..n_batches {
            // Pad the tail batch with clones of the last replica; padded
            // lanes get distinct seeds so their (discarded) streams never
            // alias an active one.
            let lane_idx = |k: usize| (b * w + k).min(n - 1);
            let lane_models: Vec<QmcModel> =
                (0..w).map(|k| models[lane_idx(k)].clone()).collect();
            let lane_states: Vec<Vec<f32>> =
                (0..w).map(|k| states[lane_idx(k)].clone()).collect();
            let lane_seeds: Vec<u32> = (0..w)
                .map(|k| {
                    let i = b * w + k;
                    if i < n {
                        seeds[i]
                    } else {
                        // off-ladder stream, disjoint from every active seed
                        seeds[n - 1] ^ 0x8000_0000 ^ (i as u32)
                    }
                })
                .collect();
            let betas: Vec<f32> = (0..w).map(|k| ladder.beta(lane_idx(k))).collect();
            batches.push(crate::engine::builder::instantiate_batch(
                plan.resolved(),
                &lane_models,
                &lane_states,
                &lane_seeds,
                exp,
            )?);
            lane_betas.push(betas);
        }
        Ok(Self {
            ladder,
            kind,
            width: w,
            batches,
            lane_betas,
            stats: vec![SweepStats::default(); n],
            swap_rng: Mt19937::new(swap_seed),
            round: 0,
            swaps_attempted: 0,
            swaps_accepted: 0,
        })
    }

    pub fn kind(&self) -> SweepKind {
        self.kind
    }

    /// Active replicas (= ladder rungs; padding excluded).
    pub fn len(&self) -> usize {
        self.ladder.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ladder.is_empty()
    }

    /// Lane width `W` of the batches.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Number of lane-batches (last one possibly padded).
    pub fn n_batches(&self) -> usize {
        self.batches.len()
    }

    pub fn ladder(&self) -> &Ladder {
        &self.ladder
    }

    /// Sweep phase of one round: every batch for `n_sweeps`, each lane at
    /// its rung's β.  (The coordinator parallelises this over batches via
    /// `scheduler::parallel_sweep_batches`.)
    pub fn sweep_all(&mut self, n_sweeps: usize) {
        let n = self.ladder.len();
        let w = self.width;
        for (b, batch) in self.batches.iter_mut().enumerate() {
            let per_lane = batch.run(n_sweeps, &self.lane_betas[b]);
            for (k, s) in per_lane.iter().enumerate() {
                let i = b * w + k;
                if i < n {
                    self.stats[i].merge(s);
                }
            }
        }
    }

    /// Exchange phase of one round — identical schedule and acceptance
    /// rule to the per-replica ensemble (the shared [`exchange_pass`]).
    pub fn exchange(&mut self) {
        let start = (self.round % 2) as usize;
        self.round += 1;
        let mut view = BatchedReplicas {
            ladder: &self.ladder,
            batches: self.batches.as_mut_slice(),
            width: self.width,
        };
        let (attempted, accepted) = exchange_pass(&mut view, &mut self.swap_rng, start);
        self.swaps_attempted += attempted;
        self.swaps_accepted += accepted;
    }

    /// One full round: sweep batch + exchange.
    pub fn round(&mut self, sweeps_per_round: usize) {
        self.sweep_all(sweeps_per_round);
        self.exchange();
    }

    /// Fraction of attempted exchanges accepted.
    pub fn swap_acceptance(&self) -> f64 {
        if self.swaps_attempted == 0 {
            0.0
        } else {
            self.swaps_accepted as f64 / self.swaps_attempted as f64
        }
    }

    /// State of replica `i` in original order.
    pub fn state_of(&mut self, i: usize) -> Vec<f32> {
        assert!(i < self.ladder.len());
        self.batches[i / self.width].state_of(i % self.width)
    }

    /// Overwrite replica `i`'s state (checkpoint restore).
    pub fn set_state_of(&mut self, i: usize, s: &[f32]) {
        assert!(i < self.ladder.len());
        self.batches[i / self.width].set_state_of(i % self.width, s);
    }

    /// Worst incremental-field inconsistency across every batch.
    pub fn validate(&mut self) -> f64 {
        self.batches.iter_mut().map(|b| b.validate()).fold(0.0f64, f64::max)
    }

    /// Per-rung reports (active replicas, ladder-ordered).
    pub fn reports(&mut self) -> Vec<ReplicaReport> {
        let w = self.width;
        (0..self.ladder.len())
            .map(|i| ReplicaReport {
                beta: self.ladder.beta(i),
                stats: self.stats[i],
                energy: self.batches[i / w].energy_of(i % w),
            })
            .collect()
    }

    // -- checkpoint support (bit-exact resume) ----------------------------

    /// Per-batch serialized RNG states.
    pub fn rng_states(&self) -> Vec<Vec<u32>> {
        self.batches.iter().map(|b| b.rng_state()).collect()
    }

    /// Restore per-batch RNG states; `false` on any mismatch.
    pub fn set_rng_states(&mut self, states: &[Vec<u32>]) -> bool {
        states.len() == self.batches.len()
            && self
                .batches
                .iter_mut()
                .zip(states)
                .all(|(b, words)| b.set_rng_state(words))
    }

    /// Serialized exchange-RNG state.
    pub fn swap_rng_state(&self) -> Vec<u32> {
        self.swap_rng.state_words()
    }

    /// Restore the exchange-RNG state; `false` on a malformed payload.
    pub fn set_swap_rng_state(&mut self, words: &[u32]) -> bool {
        self.swap_rng.restore_words(words)
    }

    /// Exchange-round counter (even/odd pairing parity).
    pub fn round_index(&self) -> u64 {
        self.round
    }

    /// Restore the exchange-round counter (checkpoint resume).
    pub fn set_round_index(&mut self, round: u64) {
        self.round = round;
    }

    /// Mutable access for the coordinator's parallel sweep phase:
    /// `(per-batch betas, batches, per-replica stats, width)`.  Stats are
    /// ladder-ordered, so batch `b`'s active lanes map onto
    /// `stats[b*w..]` — `stats.chunks_mut(w)` aligns with `batches`.
    #[allow(clippy::type_complexity)]
    pub(crate) fn split_mut(
        &mut self,
    ) -> (&[Vec<f32>], &mut [Box<dyn BatchSweeper + Send>], &mut [SweepStats], usize) {
        (&self.lane_betas, &mut self.batches, &mut self.stats, self.width)
    }
}

/// [`ReplicaSet`] view mapping global replica indices onto (batch, lane).
struct BatchedReplicas<'a> {
    ladder: &'a Ladder,
    batches: &'a mut [Box<dyn BatchSweeper + Send>],
    width: usize,
}

impl ReplicaSet for BatchedReplicas<'_> {
    fn n_replicas(&self) -> usize {
        self.ladder.len()
    }

    fn beta_of(&self, i: usize) -> f32 {
        self.ladder.beta(i)
    }

    fn energy_of(&mut self, i: usize) -> f64 {
        self.batches[i / self.width].energy_of(i % self.width)
    }

    fn state_of(&mut self, i: usize) -> Vec<f32> {
        self.batches[i / self.width].state_of(i % self.width)
    }

    fn set_state_of(&mut self, i: usize, s: &[f32]) {
        self.batches[i / self.width].set_state_of(i % self.width, s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::builder::torus_workload;

    fn build(n: usize, kind: SweepKind) -> BatchedPtEnsemble {
        let ladder = Ladder::geometric(2.0, 0.2, n);
        let wl = torus_workload(4, 4, 8, 7, 0.3);
        let models = vec![wl.model.clone(); n];
        let states = vec![wl.s0.clone(); n];
        let seeds: Vec<u32> = (0..n as u32).map(|i| 100 + i).collect();
        BatchedPtEnsemble::new(ladder, kind, &models, &states, &seeds, 999, ExpMode::Fast)
            .unwrap()
    }

    #[test]
    fn padded_tail_batch_keeps_active_counts() {
        // 6 replicas at W=4 -> 2 batches, 2 padded lanes.
        let mut pt = build(6, SweepKind::C1ReplicaBatch);
        assert_eq!(pt.len(), 6);
        assert_eq!(pt.n_batches(), 2);
        pt.sweep_all(5);
        let reports = pt.reports();
        assert_eq!(reports.len(), 6);
        for r in &reports {
            assert_eq!(r.stats.attempts, 5 * 4 * 4 * 8);
        }
    }

    #[test]
    fn hot_replicas_flip_more() {
        let mut pt = build(6, SweepKind::C1ReplicaBatch);
        pt.sweep_all(40);
        let reports = pt.reports();
        let cold = reports.first().unwrap().stats.flip_prob();
        let hot = reports.last().unwrap().stats.flip_prob();
        assert!(hot > cold, "hot {hot} should flip more than cold {cold}");
    }

    #[test]
    fn exchange_preserves_state_multiset_across_batch_boundaries() {
        let mut pt = build(6, SweepKind::C1ReplicaBatch);
        pt.sweep_all(5);
        let fingerprint = |pt: &mut BatchedPtEnsemble| -> Vec<Vec<u32>> {
            (0..pt.len())
                .map(|i| pt.state_of(i).iter().map(|x| x.to_bits()).collect())
                .collect()
        };
        let mut before = fingerprint(&mut pt);
        pt.exchange();
        pt.exchange(); // cover the odd parity (incl. the 3/4 pair)
        let mut after = fingerprint(&mut pt);
        before.sort();
        after.sort();
        assert_eq!(before, after, "exchange must permute states, not mutate them");
    }

    #[test]
    fn rounds_accumulate_stats_and_swap() {
        let mut pt = build(8, SweepKind::C1ReplicaBatchW8);
        for _ in 0..10 {
            pt.round(5);
        }
        assert!(pt.swap_acceptance() > 0.0, "dense ladder should accept some swaps");
        assert!(pt.validate() < 1e-3);
    }

    #[test]
    fn rejects_non_batch_kinds_and_bad_arity() {
        let ladder = Ladder::geometric(2.0, 0.2, 4);
        let wl = torus_workload(4, 4, 8, 7, 0.3);
        let models = vec![wl.model.clone(); 4];
        let states = vec![wl.s0.clone(); 4];
        let seeds = vec![1u32, 2, 3, 4];
        assert!(BatchedPtEnsemble::new(
            ladder.clone(),
            SweepKind::A4Full,
            &models,
            &states,
            &seeds,
            1,
            ExpMode::Fast
        )
        .is_err());
        assert!(BatchedPtEnsemble::new(
            ladder,
            SweepKind::C1ReplicaBatch,
            &models[..3],
            &states,
            &seeds,
            1,
            ExpMode::Fast
        )
        .is_err());
    }
}
