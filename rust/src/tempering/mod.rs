//! Parallel tempering (replica exchange) — the simulation context the
//! paper's workload runs in ("the optimized implementations were
//! developed in a Quantum Monte Carlo simulation context and use Parallel
//! Tempering", §1; the 115 Ising models of §4 are one tempering ladder,
//! Fig 14: "models with lower indices ... represent lower effective
//! temperatures").
//!
//! * [`ladder`] — inverse-temperature ladders (geometric by default);
//! * [`pt`]     — the replica-exchange engine over any [`crate::sweep::Sweeper`];
//! * [`batch`]  — the ladder grouped into lane-batches for the C-rungs
//!   (one SIMD lane per replica), exchanges still on the coordinator.

pub mod batch;
pub mod ladder;
pub mod pt;

pub use batch::BatchedPtEnsemble;
pub use ladder::Ladder;
pub use pt::{exchange_pass, LocalPtEnsemble, PtEnsemble, PtEnsembleImpl, ReplicaReport, ReplicaSet};
