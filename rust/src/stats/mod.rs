//! Small statistics toolkit used by the coordinator, the tempering engine
//! and the benchmark harness.

/// Streaming mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn stderr(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }
}

/// Fixed-range histogram.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Self {
        assert!(hi > lo && n_bins > 0);
        Self { lo, hi, bins: vec![0; n_bins], underflow: 0, overflow: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let b = ((x - self.lo) / (self.hi - self.lo) * self.bins.len() as f64) as usize;
            let last = self.bins.len() - 1;
            self.bins[b.min(last)] += 1;
        }
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

/// Probability that a group of `w` independent spins with per-spin flip
/// probability `p` contains at least one flip — the paper's Fig-14
/// "probability of having to wait for a spin flip": `1 - (1-p)^w`.
pub fn wait_probability(p: f64, w: usize) -> f64 {
    1.0 - (1.0 - p).powi(w as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic set is 32/7
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins_and_edges() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        for x in [-0.1, 0.0, 0.24, 0.25, 0.99, 1.0, 2.0] {
            h.push(x);
        }
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 2);
        assert_eq!(h.bins(), &[2, 1, 0, 1]);
        assert_eq!(h.total(), 7);
    }

    #[test]
    fn wait_probability_matches_paper_examples() {
        // Paper §4: average flip chance 28.6% -> CPU(A.1) waits 28.6%,
        // A.4 (w=4) ~56.8%, GPU (w=32) ~82.8% *per-model averages*; check
        // the function against the w=1 identity and monotonicity.
        assert!((wait_probability(0.286, 1) - 0.286).abs() < 1e-12);
        let p4 = wait_probability(0.2, 4);
        assert!((p4 - (1.0 - 0.8f64.powi(4))).abs() < 1e-12);
        assert!(wait_probability(0.2, 32) > p4);
        assert_eq!(wait_probability(0.0, 32), 0.0);
        assert!((wait_probability(1.0, 7) - 1.0).abs() < 1e-12);
    }
}
