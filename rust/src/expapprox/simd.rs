//! Vector forms of the exponential approximations — compute `W` Metropolis
//! flip probabilities per call (paper: "it was important that this
//! approximation does not use lookup tables, so that it can also be
//! vectorized, i.e. to compute 4 approximate exponentials at once" — and,
//! width-generically, 8 at once on AVX2).
//!
//! [`exp_fast_wide`]/[`exp_accurate_wide`] are generic over the
//! [`SimdF32`] backend; [`exp_fast_x4`]/[`exp_accurate_x4`] are the
//! paper-width instantiations kept for the 4-lane call sites.

use super::{ACCURATE_HI, ACCURATE_LO, BIAS_BITS, LOG2_E, TWO_LN2_SQ};
use crate::simd::{F32x4, SimdF32, SimdU32};

/// `W`-wide fast approximation; lane-exact to [`super::scalar::exp_fast`]
/// (both use truncating conversion — CVTTPS2DQ vs `as i32`).
#[inline(always)]
pub fn exp_fast_wide<F: SimdF32>(x: F) -> F {
    let scaled = x * F::splat((1 << 23) as f32 * LOG2_E);
    let i = scaled.to_i32_trunc().wrapping_add(<F::U as SimdU32>::splat(BIAS_BITS as u32));
    i.bitcast_f32() * F::splat(TWO_LN2_SQ)
}

/// `W`-wide accurate approximation with the paper's "special masking".
///
/// The 4th root uses RSQRTPS twice with one Newton-Raphson refinement on
/// the *first* rsqrt (the cheap half of the paper's accuracy budget); the
/// second stays raw approximate, keeping the whole thing at ~11 cycle
/// cost parity while staying inside the Appendix error bounds.
#[inline(always)]
pub fn exp_accurate_wide<F: SimdF32>(x: F) -> F {
    // Clamp into the valid interpolation domain first; the below-range
    // lanes are zeroed by mask at the end.
    let lo = F::splat(ACCURATE_LO);
    let hi = F::splat(ACCURATE_HI - 1e-3);
    let xc = x.max(lo).min(hi);

    let scaled = xc * F::splat((1 << 25) as f32 * LOG2_E);
    let i = scaled.to_i32_trunc().wrapping_add(<F::U as SimdU32>::splat(BIAS_BITS as u32));
    // At the very bottom of the domain the interpolant is denormal, which
    // RSQRTPS flushes to +inf (NaN after the refinement).  Clamp to the
    // smallest normal: its 4th root (~3.3e-10 = e^-21.83) is exactly the
    // correct boundary value.
    let interp = (i.bitcast_f32() * F::splat(TWO_LN2_SQ)).max(F::splat(f32::MIN_POSITIVE));

    // v^(1/4) = rsqrt(rsqrt(v)); refine the inner rsqrt one NR step:
    // r' = r * (1.5 - 0.5 * v * r * r).
    let r = interp.rsqrt_approx();
    let half_v = interp * F::splat(0.5);
    let r = r * (F::splat(1.5) - half_v * r * r);
    let root4 = r.rsqrt_approx();

    // Mask: 0.0 where x < ACCURATE_LO (strictly below the domain) —
    // the paper's "special masking to produce 0.0 for all x < -31.5 ln 2".
    let below = x.lt(lo);
    let masked =
        <F::U as SimdU32>::select(below, <F::U as SimdU32>::zero(), root4.bitcast_u32()).bitcast_f32();

    // Clamp: "at least 1.0 for x > 0" — keep the raw value on negative
    // lanes, take max(1.0, value) on non-negative lanes.
    let neg = x.lt(F::zero());
    let clamped = masked.max(F::splat(1.0));
    F::select_bits(neg, masked, clamped)
}

/// 4-wide fast approximation (the paper's width).
#[inline(always)]
pub fn exp_fast_x4(x: F32x4) -> F32x4 {
    exp_fast_wide(x)
}

/// 4-wide accurate approximation (the paper's width).
#[inline(always)]
pub fn exp_accurate_x4(x: F32x4) -> F32x4 {
    exp_accurate_wide(x)
}
