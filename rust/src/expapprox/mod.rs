//! Bit-trick exponential approximations (paper §2.4 + Appendix).
//!
//! Replaces the ~83-cycle library `exp` with approximations built on the
//! IEEE-754 binary32 layout: the integer bit pattern of a positive float
//! *is* a linear interpolation of `2^y` in `y = i/2^23 - 127`, so an
//! exponential costs one multiply, one float→int conversion, one integer
//! add and one bitcast.  Scaling by `2 ln² 2` centres the relative error
//! at zero.
//!
//! * [`exp_fast`] / [`simd::exp_fast_x4`] — the ~4-cycle variant: relative
//!   error in (−3.92%, +2.00%); valid for `−126 ln 2 ≤ x < 128 ln 2`.
//! * [`exp_accurate`] / [`simd::exp_accurate_x4`] — the ~11-cycle variant:
//!   interpolates `2^{4y}` and takes a 4th root (via reciprocal square
//!   roots), with masking to return exactly 0.0 below `−31.5 ln 2` and at
//!   least 1.0 for `x ≥ 0`; relative error in (−1.0%, +0.5%).
//!
//! Both are lookup-table free *by design* so that they vectorize — the
//! paper's stated reason ("It was important that this approximation does
//! not use lookup tables, so that it can also be vectorized").

pub mod scalar;
pub mod simd;

pub use scalar::{exp_accurate, exp_fast};

use std::f32::consts::LN_2;

/// `log2(e)` as f32 (the multiplier before the float→int conversion).
pub const LOG2_E: f32 = std::f32::consts::LOG2_E;
/// The error-centering constant `2 ln² 2 ≈ 0.960906`.
pub const TWO_LN2_SQ: f32 = 2.0 * LN_2 * LN_2;
/// IEEE-754 exponent bias shifted into place: `127 << 23`.
pub const BIAS_BITS: i32 = 127 << 23;

/// Domain of the fast variant: `[-126 ln 2, 128 ln 2)`.
pub const FAST_LO: f32 = -126.0 * LN_2;
/// Upper end of the fast variant's domain.
pub const FAST_HI: f32 = 128.0 * LN_2;
/// Domain of the accurate variant: `[-31.5 ln 2, 32 ln 2)`.
pub const ACCURATE_LO: f32 = -31.5 * LN_2;
/// Upper end of the accurate variant's domain.
pub const ACCURATE_HI: f32 = 32.0 * LN_2;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::F32x4;

    fn sweep(lo: f32, hi: f32, n: usize) -> impl Iterator<Item = f32> {
        let step = (hi - lo) / n as f32;
        (0..n).map(move |i| lo + step * i as f32)
    }

    /// Paper Fig 17: fast variant error within roughly (−4%, +2%).
    #[test]
    fn fast_error_bounds() {
        let (mut lo, mut hi) = (0.0f64, 0.0f64);
        for x in sweep(FAST_LO + 0.1, FAST_HI - 0.1, 400_000) {
            let approx = exp_fast(x) as f64;
            let exact = (x as f64).exp();
            let rel = approx / exact - 1.0;
            lo = lo.min(rel);
            hi = hi.max(rel);
        }
        assert!(lo > -0.0400, "worst underestimate {lo}");
        assert!(hi < 0.0205, "worst overestimate {hi}");
        // The error must actually oscillate (it averages ~0 by design).
        assert!(lo < -0.030 && hi > 0.015, "range ({lo}, {hi}) suspiciously tight");
    }

    /// Paper Appendix: accurate variant error within (−0.01, 0.005).
    #[test]
    fn accurate_error_bounds() {
        for x in sweep(ACCURATE_LO + 1e-3, -1e-3, 400_000) {
            let approx = exp_accurate(x) as f64;
            let exact = (x as f64).exp();
            let rel = approx / exact - 1.0;
            assert!(rel > -0.0101 && rel < 0.0051, "x={x} rel={rel}");
        }
    }

    #[test]
    fn accurate_masks_below_range_to_zero() {
        for x in [-22.0f32, -30.0, -100.0, -1e4, f32::NEG_INFINITY] {
            assert_eq!(exp_accurate(x), 0.0, "x={x}");
        }
    }

    #[test]
    fn accurate_is_at_least_one_for_non_negative() {
        for x in sweep(0.0, ACCURATE_HI - 0.1, 10_000) {
            assert!(exp_accurate(x) >= 1.0, "x={x} -> {}", exp_accurate(x));
        }
    }

    #[test]
    fn fast_agrees_at_powers_of_two_knots() {
        // At integer y = x/ln2 the interpolation is exact, so the only
        // error is the 2 ln² 2 scaling.
        for k in -20..20 {
            let x = (k as f32) * LN_2;
            let rel = exp_fast(x) as f64 / (x as f64).exp() - 1.0;
            assert!((rel - (TWO_LN2_SQ as f64 - 1.0)).abs() < 2e-3, "k={k} rel={rel}");
        }
    }

    #[test]
    fn simd_fast_matches_scalar_bitexact() {
        for x in sweep(FAST_LO + 0.1, FAST_HI - 0.1, 40_000) {
            let quad = simd::exp_fast_x4(F32x4::from([x, x / 2.0, -x / 3.0, 0.0])).to_array();
            for (lane, &xx) in [x, x / 2.0, -x / 3.0, 0.0].iter().enumerate() {
                if xx >= FAST_LO && xx < FAST_HI {
                    assert_eq!(quad[lane], exp_fast(xx), "x={xx}");
                }
            }
        }
    }

    #[test]
    fn simd_accurate_within_paper_bounds() {
        // The SSE variant uses RSQRTPS (max rel error 1.5*2^-12 per use),
        // so its bound is the paper's (−1%, +0.5%) plus ~0.06%.
        for x in sweep(ACCURATE_LO + 1e-3, -1e-3, 100_000) {
            let approx = simd::exp_accurate_x4(F32x4::splat(x)).to_array()[0] as f64;
            let exact = (x as f64).exp();
            let rel = approx / exact - 1.0;
            assert!(rel > -0.0108 && rel < 0.0058, "x={x} rel={rel}");
        }
    }

    #[test]
    fn simd_accurate_masks_and_clamps() {
        let v = simd::exp_accurate_x4(F32x4::from([-30.0, -22.5, 0.0, 1.5])).to_array();
        assert_eq!(v[0], 0.0);
        assert_eq!(v[1], 0.0);
        assert!(v[2] >= 1.0);
        assert!(v[3] >= 1.0);
    }

    #[test]
    fn wide_fast_is_lane_exact_to_scalar_at_w8() {
        use crate::simd::portable::F32xN;
        for x in sweep(FAST_LO + 0.1, FAST_HI - 0.1, 20_000) {
            let xs: [f32; 8] = std::array::from_fn(|k| x / (k as f32 + 1.0));
            let oct = simd::exp_fast_wide(F32xN::<8>::from(xs)).to_array();
            for (lane, &xx) in xs.iter().enumerate() {
                if xx >= FAST_LO && xx < FAST_HI {
                    assert_eq!(oct[lane], exp_fast(xx), "x={xx}");
                }
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_exp_variants_match_paper_bounds() {
        use crate::simd::avx2::F32x8;
        if !crate::simd::avx2_available() {
            eprintln!("skipping avx2 exp test: host has no AVX2");
            return;
        }
        for x in sweep(FAST_LO + 0.1, FAST_HI - 0.1, 20_000) {
            // fast: lane-exact to scalar (same CVTTPS2DQ semantics).
            let oct = simd::exp_fast_wide(F32x8::splat(x)).to_array();
            assert_eq!(oct[0], exp_fast(x), "x={x}");
            assert_eq!(oct[7], exp_fast(x), "x={x}");
        }
        // accurate: VRSQRTPS has the SSE error spec, so the SSE bound holds.
        for x in sweep(ACCURATE_LO + 1e-3, -1e-3, 50_000) {
            let approx = simd::exp_accurate_wide(F32x8::splat(x)).to_array()[0] as f64;
            let exact = (x as f64).exp();
            let rel = approx / exact - 1.0;
            assert!(rel > -0.0108 && rel < 0.0058, "x={x} rel={rel}");
        }
        let v = simd::exp_accurate_wide(F32x8::from([-30.0, -22.5, 0.0, 1.5, -5.0, -1.0, 2.0, 0.5]))
            .to_array();
        assert_eq!(v[0], 0.0);
        assert_eq!(v[1], 0.0);
        assert!(v[2] >= 1.0);
        assert!(v[3] >= 1.0);
    }

    /// The average relative error of the fast variant should be near zero
    /// (that is what the 2 ln² 2 factor buys — Appendix).
    #[test]
    fn fast_error_averages_near_zero() {
        let mut acc = 0.0f64;
        let mut n = 0u64;
        for x in sweep(-10.0, 10.0, 200_000) {
            acc += exp_fast(x) as f64 / (x as f64).exp() - 1.0;
            n += 1;
        }
        let mean = acc / n as f64;
        assert!(mean.abs() < 2e-3, "mean relative error {mean}");
    }
}
