//! Scalar forms of the exponential approximations.
//!
//! These are the non-SSE reference used by the A.2 rung and by the tests;
//! the operation sequence matches the paper's Figure 7 exactly so the SIMD
//! versions in [`super::simd`] can be validated lane-by-lane against them.

use super::{BIAS_BITS, LOG2_E, TWO_LN2_SQ};

/// Fast approximation (paper §2.4, "4 clock cycles").
///
/// `e^x ≈ bitcast<f32>( trunc(x · 2²³ log₂e) + (127 << 23) ) · 2 ln² 2`
///
/// No range masking — the caller must keep `x` in `[-126 ln 2, 128 ln 2)`,
/// as in the paper ("The faster, less accurate approximation skips the
/// bounds checking").
#[inline(always)]
pub fn exp_fast(x: f32) -> f32 {
    let i = (x * ((1 << 23) as f32 * LOG2_E)) as i32 + BIAS_BITS;
    f32::from_bits(i as u32) * TWO_LN2_SQ
}

/// Accurate approximation (paper Fig 7, "11 clock cycles").
///
/// Interpolates `2^{4y}` (factor `2²⁵ log₂e`) and takes the 4th root, with
/// the masking the paper describes: exactly `0.0` for `x < -31.5 ln 2`,
/// and at least `1.0` for `x ≥ 0` (the Metropolis `min(1, e^x)` semantics
/// never rejects a downhill move).
#[inline(always)]
pub fn exp_accurate(x: f32) -> f32 {
    if x < super::ACCURATE_LO {
        return 0.0;
    }
    let xc = if x >= super::ACCURATE_HI { super::ACCURATE_HI - 1e-3 } else { x };
    let i = (xc * ((1 << 25) as f32 * LOG2_E)) as i32 + BIAS_BITS;
    let interp = f32::from_bits(i as u32) * TWO_LN2_SQ;
    // 4th root via two square roots (the SIMD form uses RSQRTPS twice).
    let r = interp.sqrt().sqrt();
    if x >= 0.0 && r < 1.0 {
        1.0
    } else {
        r
    }
}
