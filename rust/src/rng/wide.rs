//! W-way interlaced MT19937 — the host twin of the accelerator's
//! `(624, W)` generator (paper §3.2: "the GPU version of the code has a
//! random number generator for each GPU thread ... interlacing the random
//! number generators was implemented simply by swapping the order of two
//! array indices").
//!
//! Lane `k` is bit-exact to a scalar [`super::Mt19937`] seeded with
//! `seeds[k]`, and the block layout (row r, lane k) matches the python
//! kernel's `(624, W)` buffer exactly, which the integration tests use to
//! cross-check rust against the AOT artifacts.

use super::{seed_array, u32_to_unit_f32, LOWER_MASK, MATRIX_A, M, N, UPPER_MASK};

/// W interlaced Mersenne Twisters (row-major `(624, W)` state).
#[derive(Clone)]
pub struct Mt19937Wide {
    w: usize,
    /// Row-major state: word `i` of lane `k` at `mt[w*i + k]`.
    mt: Vec<u32>,
    out: Vec<u32>,
    row: usize,
}

impl Mt19937Wide {
    pub fn new(seeds: &[u32]) -> Self {
        let w = seeds.len();
        assert!(w > 0, "need at least one lane");
        let mut mt = vec![0u32; w * N];
        for (k, &s) in seeds.iter().enumerate() {
            let lane = seed_array(s);
            for i in 0..N {
                mt[w * i + k] = lane[i];
            }
        }
        Self { w, mt, out: vec![0u32; w * N], row: N }
    }

    /// Number of interlaced lanes.
    pub fn lanes(&self) -> usize {
        self.w
    }

    /// Raw `(624, W)` state snapshot (row-major) — feeds the accelerator
    /// artifacts' `mt` input buffer.
    pub fn state_rows(&self) -> &[u32] {
        &self.mt
    }

    fn generate(&mut self) {
        let w = self.w;
        let mt = &mut self.mt;
        for i in 0..N {
            let (i1, im) = ((i + 1) % N, (i + M) % N);
            for k in 0..w {
                let y = (mt[w * i + k] & UPPER_MASK) | (mt[w * i1 + k] & LOWER_MASK);
                mt[w * i + k] =
                    mt[w * im + k] ^ (y >> 1) ^ if y & 1 == 1 { MATRIX_A } else { 0 };
            }
        }
        for (o, &v) in self.out.iter_mut().zip(mt.iter()) {
            let mut y = v;
            y ^= y >> 11;
            y ^= (y << 7) & 0x9d2c_5680;
            y ^= (y << 15) & 0xefc6_0000;
            *o = y ^ (y >> 18);
        }
        self.row = 0;
    }

    /// Next row of the block: one output from each of the W lanes.
    #[inline]
    pub fn next_row(&mut self) -> &[u32] {
        if self.row >= N {
            self.generate();
        }
        let r = self.row;
        self.row += 1;
        &self.out[self.w * r..self.w * (r + 1)]
    }

    /// Next row mapped to uniforms in `[0, 1)`, appended to `dst`.
    pub fn next_row_f32_into(&mut self, dst: &mut Vec<f32>) {
        let w = self.w;
        if self.row >= N {
            self.generate();
        }
        let r = self.row;
        self.row += 1;
        dst.extend(self.out[w * r..w * (r + 1)].iter().map(|&u| u32_to_unit_f32(u)));
    }
}
