//! W-way interlaced MT19937 on a SIMD backend — the width-generic form of
//! the paper's §3 explicitly vectorized generator (Figures 8–10).
//!
//! State is `W`×624 words laid out as 624 `W`-tuples: word `i` of
//! generator `k` lives at `state[W*i + k]`, so one vector load fetches
//! word `i` of all `W` generators and every operation of the reference
//! algorithm becomes a single SIMD instruction on the tuple.  The ternary
//! `(y & 1) ? MATRIX_A : 0` becomes the Figure-10 mask sequence
//! (PCMPEQD + PAND) — branch-free, like the paper's assembly.
//!
//! The backend `U` decides both the lane count and the instruction set:
//! `Mt19937Simd<U32x4>` is the paper's 4-way SSE generator (also exported
//! as [`super::Mt19937x4`]), `Mt19937Simd<avx2::U32x8>` the 8-way AVX2 one, and
//! `Mt19937Simd<portable::U32xN<W>>` runs any width anywhere.  Lane `k`
//! is always bit-exact to a scalar [`super::Mt19937`] seeded with
//! `seeds[k]`, and the `(624, W)` block layout matches
//! [`super::Mt19937Wide`] and the accelerator kernels.

use std::marker::PhantomData;

use super::{seed_array, MATRIX_A, M, N};
use crate::simd::{SimdF32, SimdU32};

/// `W` interlaced Mersenne Twisters advanced in SIMD lock-step.
#[derive(Clone)]
pub struct Mt19937Simd<U: SimdU32> {
    /// Interlaced state: word `i` of lane `k` at `mt[W*i + k]`.
    mt: Vec<u32>,
    /// Tempered output buffer for the current block, same interlacing.
    out: Vec<u32>,
    idx: usize,
    _backend: PhantomData<U>,
}

impl<U: SimdU32> Mt19937Simd<U> {
    /// Seed the `W` lanes independently (the paper interlaces "4 MT19937
    /// random number generators with different seeds"); `seeds.len()`
    /// must equal the backend's lane count.
    pub fn new(seeds: &[u32]) -> Self {
        let w = U::LANES;
        assert_eq!(seeds.len(), w, "need exactly {w} seeds for a {w}-lane generator");
        let mut mt = vec![0u32; w * N];
        for (k, &s) in seeds.iter().enumerate() {
            let lane = seed_array(s);
            for i in 0..N {
                mt[w * i + k] = lane[i];
            }
        }
        Self { mt, out: vec![0u32; w * N], idx: N, _backend: PhantomData }
    }

    /// Seed lanes with the consecutive values `seed, seed+1, …, seed+W-1`
    /// — the convention the A.3/A.4 sweeps use, width-generic.
    pub fn from_base_seed(seed: u32) -> Self {
        let seeds: Vec<u32> = (0..U::LANES as u32).map(|k| seed.wrapping_add(k)).collect();
        Self::new(&seeds)
    }

    /// Number of interlaced lanes.
    pub fn lanes(&self) -> usize {
        U::LANES
    }

    /// Regenerate + temper the whole `W`×624 block.
    ///
    /// The loop body is the reference algorithm with every scalar op
    /// replaced by its `W`-wide counterpart — the paper's "one can
    /// conceptually just change the type of `data` and `y` from single
    /// 32-bit integers to quadruplets".
    fn generate(&mut self) {
        U::with_features(|| self.generate_block());
    }

    #[inline(always)]
    fn generate_block(&mut self) {
        let w = U::LANES;
        let upper = U::splat(super::UPPER_MASK);
        let lower = U::splat(super::LOWER_MASK);
        let matrix = U::splat(MATRIX_A);
        for i in 0..N {
            let cur = U::load(&self.mt[w * i..]);
            let nxt = U::load(&self.mt[w * ((i + 1) % N)..]);
            let src = U::load(&self.mt[w * ((i + M) % N)..]);
            let y = (cur & upper) | (nxt & lower);
            // Figure 10: mask = (y & 1 == 1) ? ~0 : 0; xor-in (mask & MATRIX_A)
            let mag = y.lsb_mask() & matrix;
            let new = src ^ y.shr(1) ^ mag;
            new.store(&mut self.mt[w * i..w * (i + 1)]);
        }
        // Temper the block in one vector pass.
        for i in 0..N {
            let mut y = U::load(&self.mt[w * i..]);
            y = y ^ y.shr(11);
            y = y ^ (y.shl(7) & U::splat(0x9d2c_5680));
            y = y ^ (y.shl(15) & U::splat(0xefc6_0000));
            y = y ^ y.shr(18);
            y.store(&mut self.out[w * i..w * (i + 1)]);
        }
        self.idx = 0;
    }

    /// Next `W`-tuple of raw outputs as a SIMD register (no round-trip
    /// through memory lanes — the hot-path form used by the A.3/A.4
    /// sweeps).
    #[inline]
    pub fn next_vec(&mut self) -> U {
        if self.idx >= N {
            self.generate();
        }
        let v = U::load(&self.out[U::LANES * self.idx..]);
        self.idx += 1;
        v
    }

    /// Next `W`-tuple of uniforms in `[0, 1)` (top 24 bits per lane).
    #[inline]
    pub fn next_vec_f32(&mut self) -> U::F {
        let bits = self.next_vec();
        // (u >> 8) fits in 24 bits, so the signed int→float conversion is
        // exact and positive.
        bits.shr(8).to_f32_from_i32() * <U::F as SimdF32>::splat(1.0 / 16_777_216.0)
    }

    /// Next `W` raw outputs written to `dst[..W]` (test/inspection form).
    #[inline]
    pub fn next_into(&mut self, dst: &mut [u32]) {
        self.next_vec().store(dst);
    }

    /// Serialize the full interlaced state (`W`×624 raw words, the
    /// tempered output block and the cursor) so a checkpointed trajectory
    /// can resume bit-exactly on every lane.
    pub fn state_words(&self) -> Vec<u32> {
        let n = U::LANES * N;
        let mut out = Vec::with_capacity(2 * n + 1);
        out.extend_from_slice(&self.mt);
        out.extend_from_slice(&self.out);
        out.push(self.idx as u32);
        out
    }

    /// Restore a state captured by [`Self::state_words`]; returns `false`
    /// (leaving the generator untouched) on a malformed payload.
    pub fn restore_words(&mut self, words: &[u32]) -> bool {
        let n = U::LANES * N;
        if words.len() != 2 * n + 1 || words[2 * n] as usize > N {
            return false;
        }
        self.mt.copy_from_slice(&words[..n]);
        self.out.copy_from_slice(&words[n..2 * n]);
        self.idx = words[2 * n] as usize;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::portable;

    #[test]
    fn state_words_roundtrip_resumes_every_lane_bit_exactly() {
        type U = portable::U32xN<4>;
        let mut a = Mt19937Simd::<U>::from_base_seed(777);
        let mut row = [0u32; 4];
        for _ in 0..1000 {
            a.next_into(&mut row); // leave the cursor mid-block
        }
        let snap = a.state_words();
        let mut expect = Vec::new();
        for _ in 0..700 {
            a.next_into(&mut row);
            expect.push(row);
        }
        let mut b = Mt19937Simd::<U>::from_base_seed(1);
        assert!(b.restore_words(&snap));
        for (step, want) in expect.iter().enumerate() {
            b.next_into(&mut row);
            assert_eq!(&row, want, "step {step}");
        }
        // wrong width or truncated payloads are rejected
        let mut w8 = Mt19937Simd::<portable::U32xN<8>>::from_base_seed(1);
        assert!(!w8.restore_words(&snap));
        assert!(!b.restore_words(&snap[..snap.len() - 1]));
    }
}
