//! W-way interlaced MT19937 on a SIMD backend — the width-generic form of
//! the paper's §3 explicitly vectorized generator (Figures 8–10).
//!
//! State is `W`×624 words laid out as 624 `W`-tuples: word `i` of
//! generator `k` lives at `state[W*i + k]`, so one vector load fetches
//! word `i` of all `W` generators and every operation of the reference
//! algorithm becomes a single SIMD instruction on the tuple.  The ternary
//! `(y & 1) ? MATRIX_A : 0` becomes the Figure-10 mask sequence
//! (PCMPEQD + PAND) — branch-free, like the paper's assembly.
//!
//! The backend `U` decides both the lane count and the instruction set:
//! `Mt19937Simd<U32x4>` is the paper's 4-way SSE generator (also exported
//! as [`super::Mt19937x4`]), `Mt19937Simd<avx2::U32x8>` the 8-way AVX2 one, and
//! `Mt19937Simd<portable::U32xN<W>>` runs any width anywhere.  Lane `k`
//! is always bit-exact to a scalar [`super::Mt19937`] seeded with
//! `seeds[k]`, and the `(624, W)` block layout matches
//! [`super::Mt19937Wide`] and the accelerator kernels.

use std::marker::PhantomData;

use super::{seed_array, MATRIX_A, M, N};
use crate::simd::{SimdF32, SimdU32};

/// `W` interlaced Mersenne Twisters advanced in SIMD lock-step.
#[derive(Clone)]
pub struct Mt19937Simd<U: SimdU32> {
    /// Interlaced state: word `i` of lane `k` at `mt[W*i + k]`.
    mt: Vec<u32>,
    /// Tempered output buffer for the current block, same interlacing.
    out: Vec<u32>,
    idx: usize,
    _backend: PhantomData<U>,
}

impl<U: SimdU32> Mt19937Simd<U> {
    /// Seed the `W` lanes independently (the paper interlaces "4 MT19937
    /// random number generators with different seeds"); `seeds.len()`
    /// must equal the backend's lane count.
    pub fn new(seeds: &[u32]) -> Self {
        let w = U::LANES;
        assert_eq!(seeds.len(), w, "need exactly {w} seeds for a {w}-lane generator");
        let mut mt = vec![0u32; w * N];
        for (k, &s) in seeds.iter().enumerate() {
            let lane = seed_array(s);
            for i in 0..N {
                mt[w * i + k] = lane[i];
            }
        }
        Self { mt, out: vec![0u32; w * N], idx: N, _backend: PhantomData }
    }

    /// Seed lanes with the consecutive values `seed, seed+1, …, seed+W-1`
    /// — the convention the A.3/A.4 sweeps use, width-generic.
    pub fn from_base_seed(seed: u32) -> Self {
        let seeds: Vec<u32> = (0..U::LANES as u32).map(|k| seed.wrapping_add(k)).collect();
        Self::new(&seeds)
    }

    /// Number of interlaced lanes.
    pub fn lanes(&self) -> usize {
        U::LANES
    }

    /// Regenerate + temper the whole `W`×624 block.
    ///
    /// The loop body is the reference algorithm with every scalar op
    /// replaced by its `W`-wide counterpart — the paper's "one can
    /// conceptually just change the type of `data` and `y` from single
    /// 32-bit integers to quadruplets".
    fn generate(&mut self) {
        let _g = crate::obs::phase::timed(crate::obs::phase::Phase::Rng);
        U::with_features(|| self.generate_block());
    }

    /// One twist step: `mt[i] = mt[src] ^ (y >> 1) ^ (lsb(y) & MATRIX_A)`
    /// with `y = (mt[i] & UPPER) | (mt[nxt] & LOWER)` (Figure 10's
    /// branch-free mask form).
    #[inline(always)]
    fn twist_one(&mut self, i: usize, nxt: usize, src: usize, upper: U, lower: U, matrix: U) {
        let w = U::LANES;
        let cur = U::load(&self.mt[w * i..]);
        let nx = U::load(&self.mt[w * nxt..]);
        let sr = U::load(&self.mt[w * src..]);
        let y = (cur & upper) | (nx & lower);
        let new = sr ^ y.shr(1) ^ (y.lsb_mask() & matrix);
        new.store(&mut self.mt[w * i..w * (i + 1)]);
    }

    #[inline(always)]
    fn temper_one(&mut self, i: usize) {
        let w = U::LANES;
        let mut y = U::load(&self.mt[w * i..]);
        y = y ^ y.shr(11);
        y = y ^ (y.shl(7) & U::splat(0x9d2c_5680));
        y = y ^ (y.shl(15) & U::splat(0xefc6_0000));
        y = y ^ y.shr(18);
        y.store(&mut self.out[w * i..w * (i + 1)]);
    }

    /// The production block step: the reference recurrence split at the
    /// `N - M` boundary (so `src` never needs a modulo inside a loop) and
    /// unrolled into independent dependency chains — 2 for the twist,
    /// 4 for the temper.  Within a twist pair every load happens before
    /// either store and the two steps touch disjoint words, so the chains
    /// carry no data dependence on each other and the core can overlap
    /// them.  Bit-exact to the rolled reference (see the test): before
    /// the boundary `cur`/`nxt`/`src` all read not-yet-twisted words, and
    /// past it `src = mt[i + M - N]` reads words already updated this
    /// pass — exactly the values the rolled loop sees through memory.
    #[inline(always)]
    fn generate_block(&mut self) {
        let w = U::LANES;
        let upper = U::splat(super::UPPER_MASK);
        let lower = U::splat(super::LOWER_MASK);
        let matrix = U::splat(MATRIX_A);
        let mut i = 0;
        while i + 1 < N - M {
            let cur0 = U::load(&self.mt[w * i..]);
            let cur1 = U::load(&self.mt[w * (i + 1)..]);
            let nxt1 = U::load(&self.mt[w * (i + 2)..]);
            let src0 = U::load(&self.mt[w * (i + M)..]);
            let src1 = U::load(&self.mt[w * (i + M + 1)..]);
            let y0 = (cur0 & upper) | (cur1 & lower);
            let y1 = (cur1 & upper) | (nxt1 & lower);
            let new0 = src0 ^ y0.shr(1) ^ (y0.lsb_mask() & matrix);
            let new1 = src1 ^ y1.shr(1) ^ (y1.lsb_mask() & matrix);
            new0.store(&mut self.mt[w * i..w * (i + 1)]);
            new1.store(&mut self.mt[w * (i + 1)..w * (i + 2)]);
            i += 2;
        }
        // N - M = 227 is odd: one remainder step before the boundary.
        while i < N - M {
            self.twist_one(i, i + 1, i + M, upper, lower, matrix);
            i += 1;
        }
        // Past the boundary `src` wraps onto words updated this pass.
        while i + 1 < N - 1 {
            let cur0 = U::load(&self.mt[w * i..]);
            let cur1 = U::load(&self.mt[w * (i + 1)..]);
            let nxt1 = U::load(&self.mt[w * (i + 2)..]);
            let src0 = U::load(&self.mt[w * (i + M - N)..]);
            let src1 = U::load(&self.mt[w * (i + M - N + 1)..]);
            let y0 = (cur0 & upper) | (cur1 & lower);
            let y1 = (cur1 & upper) | (nxt1 & lower);
            let new0 = src0 ^ y0.shr(1) ^ (y0.lsb_mask() & matrix);
            let new1 = src1 ^ y1.shr(1) ^ (y1.lsb_mask() & matrix);
            new0.store(&mut self.mt[w * i..w * (i + 1)]);
            new1.store(&mut self.mt[w * (i + 1)..w * (i + 2)]);
            i += 2;
        }
        // Final step: `nxt` wraps to the already-updated mt[0].
        while i < N {
            self.twist_one(i, (i + 1) % N, (i + M) % N, upper, lower, matrix);
            i += 1;
        }
        // Temper: four independent chains per step (N = 624 = 4 · 156).
        let mut i = 0;
        while i < N {
            self.temper_one(i);
            self.temper_one(i + 1);
            self.temper_one(i + 2);
            self.temper_one(i + 3);
            i += 4;
        }
        self.idx = 0;
    }

    /// The rolled reference form of [`Self::generate_block`], kept to pin
    /// the unrolled loops bit-exactly.
    #[cfg(test)]
    fn generate_block_rolled(&mut self) {
        let w = U::LANES;
        let upper = U::splat(super::UPPER_MASK);
        let lower = U::splat(super::LOWER_MASK);
        let matrix = U::splat(MATRIX_A);
        for i in 0..N {
            let cur = U::load(&self.mt[w * i..]);
            let nxt = U::load(&self.mt[w * ((i + 1) % N)..]);
            let src = U::load(&self.mt[w * ((i + M) % N)..]);
            let y = (cur & upper) | (nxt & lower);
            // Figure 10: mask = (y & 1 == 1) ? ~0 : 0; xor-in (mask & MATRIX_A)
            let mag = y.lsb_mask() & matrix;
            let new = src ^ y.shr(1) ^ mag;
            new.store(&mut self.mt[w * i..w * (i + 1)]);
        }
        for i in 0..N {
            let mut y = U::load(&self.mt[w * i..]);
            y = y ^ y.shr(11);
            y = y ^ (y.shl(7) & U::splat(0x9d2c_5680));
            y = y ^ (y.shl(15) & U::splat(0xefc6_0000));
            y = y ^ y.shr(18);
            y.store(&mut self.out[w * i..w * (i + 1)]);
        }
        self.idx = 0;
    }

    /// Next `W`-tuple of raw outputs as a SIMD register (no round-trip
    /// through memory lanes — the hot-path form used by the A.3/A.4
    /// sweeps).
    #[inline]
    pub fn next_vec(&mut self) -> U {
        if self.idx >= N {
            self.generate();
        }
        let v = U::load(&self.out[U::LANES * self.idx..]);
        self.idx += 1;
        v
    }

    /// Next `W`-tuple of uniforms in `[0, 1)` (top 24 bits per lane).
    #[inline]
    pub fn next_vec_f32(&mut self) -> U::F {
        let bits = self.next_vec();
        // (u >> 8) fits in 24 bits, so the signed int→float conversion is
        // exact and positive.
        bits.shr(8).to_f32_from_i32() * <U::F as SimdF32>::splat(1.0 / 16_777_216.0)
    }

    /// Next `W` raw outputs written to `dst[..W]` (test/inspection form).
    #[inline]
    pub fn next_into(&mut self, dst: &mut [u32]) {
        self.next_vec().store(dst);
    }

    /// Serialize the full interlaced state (`W`×624 raw words, the
    /// tempered output block and the cursor) so a checkpointed trajectory
    /// can resume bit-exactly on every lane.
    pub fn state_words(&self) -> Vec<u32> {
        let n = U::LANES * N;
        let mut out = Vec::with_capacity(2 * n + 1);
        out.extend_from_slice(&self.mt);
        out.extend_from_slice(&self.out);
        out.push(self.idx as u32);
        out
    }

    /// Restore a state captured by [`Self::state_words`]; returns `false`
    /// (leaving the generator untouched) on a malformed payload.
    pub fn restore_words(&mut self, words: &[u32]) -> bool {
        let n = U::LANES * N;
        if words.len() != 2 * n + 1 || words[2 * n] as usize > N {
            return false;
        }
        self.mt.copy_from_slice(&words[..n]);
        self.out.copy_from_slice(&words[n..2 * n]);
        self.idx = words[2 * n] as usize;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::portable;

    #[test]
    fn unrolled_block_generation_is_bit_exact_to_the_rolled_reference() {
        fn check<U: SimdU32>() {
            let mut a = Mt19937Simd::<U>::from_base_seed(2026);
            let mut b = a.clone();
            for round in 0..3 {
                U::with_features(|| a.generate_block());
                U::with_features(|| b.generate_block_rolled());
                assert_eq!(a.mt, b.mt, "twist diverged (round {round}, W={})", U::LANES);
                assert_eq!(a.out, b.out, "temper diverged (round {round}, W={})", U::LANES);
                assert_eq!(a.idx, b.idx);
            }
        }
        check::<portable::U32xN<4>>();
        check::<portable::U32xN<8>>();
        check::<portable::U32xN<16>>();
        #[cfg(target_arch = "x86_64")]
        if crate::simd::avx2_available() {
            check::<crate::simd::avx2::U32x8>();
        }
    }

    #[test]
    fn state_words_roundtrip_resumes_every_lane_bit_exactly() {
        type U = portable::U32xN<4>;
        let mut a = Mt19937Simd::<U>::from_base_seed(777);
        let mut row = [0u32; 4];
        for _ in 0..1000 {
            a.next_into(&mut row); // leave the cursor mid-block
        }
        let snap = a.state_words();
        let mut expect = Vec::new();
        for _ in 0..700 {
            a.next_into(&mut row);
            expect.push(row);
        }
        let mut b = Mt19937Simd::<U>::from_base_seed(1);
        assert!(b.restore_words(&snap));
        for (step, want) in expect.iter().enumerate() {
            b.next_into(&mut row);
            assert_eq!(&row, want, "step {step}");
        }
        // wrong width or truncated payloads are rejected
        let mut w8 = Mt19937Simd::<portable::U32xN<8>>::from_base_seed(1);
        assert!(!w8.restore_words(&snap));
        assert!(!b.restore_words(&snap[..snap.len() - 1]));
    }
}
