//! Mersenne Twister 19937 — scalar reference, width-generic SIMD
//! interlaced, and W-way scalar-interlaced generators.
//!
//! The paper (§3) observes that after the basic optimizations "a majority
//! of CPU time was being spent generating the large volume of random
//! numbers", and interlaces 4 MT19937 generators with different seeds so
//! that SSE advances all 4 in lock-step — "keeps 4x624 = 2,496 numbers and
//! uses SSE to generate 4 random numbers in roughly the same time as each
//! random number before".  Modern x86 doubles that: the same loop on AVX2
//! advances 8 generators per instruction.
//!
//! * [`Mt19937`]     — scalar reference (A.1/A.2 rungs), transcribed from
//!                     Matsumoto & Nishimura's published code.
//! * [`Mt19937Simd`] — W-way interlaced SIMD generator, generic over the
//!                     [`crate::simd::SimdU32`] backend: `U32x4` is the
//!                     paper's 4-way SSE form (alias [`Mt19937x4`]),
//!                     `avx2::U32x8` the 8-way AVX2 form, and the portable
//!                     lanes cover every other width/arch.  Lane `k` is
//!                     bit-exact to a scalar generator seeded with
//!                     `seeds[k]`.
//! * [`Mt19937Wide`] — W-way interlaced scalar generator (any W), the rust
//!                     twin of the accelerator's `(624, W)` kernel; used to
//!                     produce host-side streams matching the artifacts and
//!                     to seed their state buffers.
//!
//! All variants map `u32 -> f32` uniforms identically: the top 24 bits,
//! `(u >> 8) * 2^-24`, so a decision made on any rung is reproducible on
//! any other.

mod mt19937;
mod mt19937simd;
mod wide;

pub use mt19937::Mt19937;
pub use mt19937simd::Mt19937Simd;
pub use wide::Mt19937Wide;

/// The paper's 4-way interlaced SSE generator (A.3/A.4 rungs at the
/// paper's width) — [`Mt19937Simd`] on the default 4-lane backend.
pub type Mt19937x4 = Mt19937Simd<crate::simd::U32x4>;

pub(crate) const N: usize = 624;
pub(crate) const M: usize = 397;
pub(crate) const MATRIX_A: u32 = 0x9908_b0df;
pub(crate) const UPPER_MASK: u32 = 0x8000_0000;
pub(crate) const LOWER_MASK: u32 = 0x7fff_ffff;

/// Map a raw output to a uniform in `[0, 1)` with 24-bit resolution.
#[inline(always)]
pub fn u32_to_unit_f32(u: u32) -> f32 {
    (u >> 8) as f32 * (1.0 / 16_777_216.0)
}

/// `init_genrand` from the reference implementation (also used by the
/// python side's `mt19937.init_state`; keep in sync).
pub(crate) fn seed_array(seed: u32) -> [u32; N] {
    let mut mt = [0u32; N];
    mt[0] = seed;
    for i in 1..N {
        mt[i] = 1_812_433_253u32
            .wrapping_mul(mt[i - 1] ^ (mt[i - 1] >> 30))
            .wrapping_add(i as u32);
    }
    mt
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::{portable, SimdU32};

    /// First outputs of the reference MT19937 for seed 5489 (the canonical
    /// default seed) — published golden values.
    pub(crate) const GOLDEN_5489: [u32; 10] = [
        3499211612, 581869302, 3890346734, 3586334585, 545404204, 4161255391, 3922919429,
        949333985, 2715962298, 1323567403,
    ];

    #[test]
    fn scalar_matches_golden_vector() {
        let mut rng = Mt19937::new(5489);
        for (i, &want) in GOLDEN_5489.iter().enumerate() {
            assert_eq!(rng.next_u32(), want, "output {i}");
        }
    }

    /// Lane-exactness of the SIMD generator on backend `U`: every lane
    /// reproduces the scalar stream for its seed, across two twist
    /// boundaries.
    fn assert_lanes_match_scalar<U: SimdU32>(seeds: &[u32]) {
        let mut vec_rng = Mt19937Simd::<U>::new(seeds);
        let mut scalars: Vec<Mt19937> = seeds.iter().map(|&s| Mt19937::new(s)).collect();
        let mut row = vec![0u32; U::LANES];
        for step in 0..1400 {
            vec_rng.next_into(&mut row);
            for (k, &v) in row.iter().enumerate() {
                assert_eq!(v, scalars[k].next_u32(), "step {step} lane {k}");
            }
        }
    }

    #[test]
    fn x4_lanes_match_scalar_streams() {
        assert_lanes_match_scalar::<crate::simd::U32x4>(&[5489, 1, 0xdead_beef, 4294967295]);
    }

    #[test]
    fn portable_w4_and_w8_lanes_match_scalar_streams() {
        assert_lanes_match_scalar::<portable::U32xN<4>>(&[5489, 1, 0xdead_beef, 4294967295]);
        let seeds8: Vec<u32> = (0..8).map(|k| 42 + 7 * k).collect();
        assert_lanes_match_scalar::<portable::U32xN<8>>(&seeds8);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_lanes_match_scalar_streams() {
        if !crate::simd::avx2_available() {
            eprintln!("skipping avx2 MT19937 test: host has no AVX2");
            return;
        }
        let seeds8: Vec<u32> = (0..8).map(|k| 42 + 7 * k).collect();
        assert_lanes_match_scalar::<crate::simd::avx2::U32x8>(&seeds8);
    }

    #[test]
    fn from_base_seed_uses_consecutive_seeds() {
        let mut a = Mt19937Simd::<portable::U32xN<4>>::from_base_seed(100);
        let mut b = Mt19937Simd::<portable::U32xN<4>>::new(&[100, 101, 102, 103]);
        let (mut ra, mut rb) = ([0u32; 4], [0u32; 4]);
        for _ in 0..100 {
            a.next_into(&mut ra);
            b.next_into(&mut rb);
            assert_eq!(ra, rb);
        }
    }

    #[test]
    fn wide_lanes_match_scalar_streams() {
        let seeds: Vec<u32> = (0..7).map(|k| 100 + k).collect();
        let mut wide = Mt19937Wide::new(&seeds);
        let mut scalars: Vec<Mt19937> = seeds.iter().map(|&s| Mt19937::new(s)).collect();
        for step in 0..1300 {
            let row = wide.next_row();
            for (k, &v) in row.iter().enumerate() {
                assert_eq!(v, scalars[k].next_u32(), "step {step} lane {k}");
            }
        }
    }

    #[test]
    fn unit_f32_mapping_is_24_bit() {
        assert_eq!(u32_to_unit_f32(0), 0.0);
        assert_eq!(u32_to_unit_f32(u32::MAX), (16_777_215.0) / 16_777_216.0);
        assert!(u32_to_unit_f32(u32::MAX) < 1.0);
        assert_eq!(u32_to_unit_f32(1 << 8), 1.0 / 16_777_216.0);
    }

    #[test]
    fn different_seeds_decorrelate() {
        let mut a = Mt19937::new(1);
        let mut b = Mt19937::new(2);
        let same = (0..1000).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3, "streams should not collide ({same} collisions)");
    }

    #[test]
    fn next_f32_in_unit_interval() {
        let mut rng = Mt19937::new(42);
        for _ in 0..10_000 {
            let u = rng.next_f32();
            assert!((0.0..1.0).contains(&u));
        }
    }
}
