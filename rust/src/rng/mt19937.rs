//! Scalar MT19937 — the generator the paper's original (A.1/A.2) rungs
//! use, transcribed from the Matsumoto & Nishimura reference C code.

use super::{seed_array, u32_to_unit_f32, LOWER_MASK, MATRIX_A, M, N, UPPER_MASK};

/// Scalar Mersenne Twister (period 2^19937 - 1).
#[derive(Clone)]
pub struct Mt19937 {
    mt: [u32; N],
    idx: usize,
}

impl Mt19937 {
    /// Seed with `init_genrand(seed)`.
    pub fn new(seed: u32) -> Self {
        Self { mt: seed_array(seed), idx: N }
    }

    /// Regenerate all 624 words — the sequential loop of the paper's
    /// Figure 8 ("two example lines of MT19937").
    fn generate(&mut self) {
        let _g = crate::obs::phase::timed(crate::obs::phase::Phase::Rng);
        let mt = &mut self.mt;
        for i in 0..N {
            let y = (mt[i] & UPPER_MASK) | (mt[(i + 1) % N] & LOWER_MASK);
            mt[i] = mt[(i + M) % N] ^ (y >> 1) ^ if y & 1 == 1 { MATRIX_A } else { 0 };
        }
        self.idx = 0;
    }

    /// Next raw 32-bit output (tempered).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        if self.idx >= N {
            self.generate();
        }
        let mut y = self.mt[self.idx];
        self.idx += 1;
        y ^= y >> 11;
        y ^= (y << 7) & 0x9d2c_5680;
        y ^= (y << 15) & 0xefc6_0000;
        y ^ (y >> 18)
    }

    /// Next uniform in `[0, 1)` (top 24 bits).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        u32_to_unit_f32(self.next_u32())
    }

    /// Serialize the full generator state (624 words + the cursor) so a
    /// checkpointed trajectory can resume bit-exactly.
    pub fn state_words(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(N + 1);
        out.extend_from_slice(&self.mt);
        out.push(self.idx as u32);
        out
    }

    /// Restore a state captured by [`Self::state_words`]; returns `false`
    /// (leaving the generator untouched) on a malformed payload.
    pub fn restore_words(&mut self, words: &[u32]) -> bool {
        if words.len() != N + 1 || words[N] as usize > N {
            return false;
        }
        self.mt.copy_from_slice(&words[..N]);
        self.idx = words[N] as usize;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_words_roundtrip_resumes_bit_exactly() {
        let mut a = Mt19937::new(90210);
        for _ in 0..1000 {
            a.next_u32(); // cross one twist boundary
        }
        let snap = a.state_words();
        let expect: Vec<u32> = (0..700).map(|_| a.next_u32()).collect();
        let mut b = Mt19937::new(1);
        assert!(b.restore_words(&snap));
        let got: Vec<u32> = (0..700).map(|_| b.next_u32()).collect();
        assert_eq!(got, expect);
        // malformed payloads are rejected without touching state
        assert!(!b.restore_words(&snap[..N]));
        let mut bad = snap.clone();
        bad[N] = N as u32 + 5;
        assert!(!b.restore_words(&bad));
    }
}
