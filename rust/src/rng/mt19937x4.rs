//! 4-way interlaced MT19937 — the paper's §3 explicitly vectorized
//! generator (Figures 8–10).
//!
//! State is 4x624 = 2,496 words laid out as 624 quadruplets: word `i` of
//! generator `k` lives at `state[4*i + k]`, so one 128-bit load fetches
//! word `i` of all four generators and every operation of the reference
//! algorithm becomes a single SSE instruction on the quadruplet.  The
//! ternary `(y & 1) ? MATRIX_A : 0` becomes the Figure-10 mask sequence
//! (PCMPEQD + PAND) — branch-free, like the paper's assembly.

use super::{seed_array, MATRIX_A, M, N};
use crate::simd::{F32x4, U32x4};

/// 4 interlaced Mersenne Twisters advanced in SSE lock-step.
#[derive(Clone)]
pub struct Mt19937x4 {
    /// Interlaced state: word `i` of lane `k` at `mt[4*i + k]`.
    mt: Vec<u32>,
    /// Tempered output buffer for the current block, same interlacing.
    out: Vec<u32>,
    idx: usize,
}

impl Mt19937x4 {
    /// Seed the 4 lanes independently (the paper interlaces "4 MT19937
    /// random number generators with different seeds").
    pub fn new(seeds: [u32; 4]) -> Self {
        let lanes: Vec<[u32; N]> = seeds.iter().map(|&s| seed_array(s)).collect();
        let mut mt = vec![0u32; 4 * N];
        for i in 0..N {
            for k in 0..4 {
                mt[4 * i + k] = lanes[k][i];
            }
        }
        Self { mt, out: vec![0u32; 4 * N], idx: N }
    }

    /// Regenerate + temper the whole 4x624 block.
    ///
    /// The loop body is the reference algorithm with every scalar op
    /// replaced by its 4-wide counterpart — the paper's "one can
    /// conceptually just change the type of `data` and `y` from single
    /// 32-bit integers to quadruplets".
    fn generate(&mut self) {
        let upper = U32x4::splat(super::UPPER_MASK);
        let lower = U32x4::splat(super::LOWER_MASK);
        let matrix = U32x4::splat(MATRIX_A);
        let mt = &mut self.mt;
        for i in 0..N {
            let cur = U32x4::load(&mt[4 * i..]);
            let nxt = U32x4::load(&mt[4 * ((i + 1) % N)..]);
            let src = U32x4::load(&mt[4 * ((i + M) % N)..]);
            let y = (cur & upper) | (nxt & lower);
            // Figure 10: mask = (y & 1 == 1) ? ~0 : 0; xor-in (mask & MATRIX_A)
            let mag = y.lsb_mask() & matrix;
            let new = src ^ y.shr(1) ^ mag;
            new.store(&mut mt[4 * i..4 * i + 4]);
        }
        // Temper the block in one vector pass.
        for i in 0..N {
            let mut y = U32x4::load(&mt[4 * i..]);
            y = y ^ y.shr(11);
            y = y ^ (y.shl(7) & U32x4::splat(0x9d2c_5680));
            y = y ^ (y.shl(15) & U32x4::splat(0xefc6_0000));
            y = y ^ y.shr(18);
            y.store(&mut self.out[4 * i..4 * i + 4]);
        }
        self.idx = 0;
    }

    /// Next quadruplet of raw outputs — one value from each lane.
    #[inline]
    pub fn next4_u32(&mut self) -> [u32; 4] {
        self.next4().to_array()
    }

    /// Next quadruplet as a SIMD register (no round-trip through memory
    /// lanes — the hot-path form used by the A.3/A.4 sweeps).
    #[inline]
    pub fn next4(&mut self) -> U32x4 {
        if self.idx >= N {
            self.generate();
        }
        let v = U32x4::load(&self.out[4 * self.idx..]);
        self.idx += 1;
        v
    }

    /// Next quadruplet of uniforms in `[0, 1)` (top 24 bits per lane).
    #[inline]
    pub fn next4_f32(&mut self) -> F32x4 {
        let bits = self.next4();
        // (u >> 8) fits in 24 bits, so the signed CVTDQ2PS conversion is
        // exact and positive.
        bits.shr(8).to_f32_from_i32() * F32x4::splat(1.0 / 16_777_216.0)
    }
}
