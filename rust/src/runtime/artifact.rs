//! Artifact metadata: the JSON sidecars written next to each HLO file by
//! `python/compile/aot.py`, and the manifest indexing them.

use std::path::{Path, PathBuf};

use crate::util::json::Value;
use crate::Result;

/// Shape + dtype of one artifact input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<Self> {
        let shape = v
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { shape, dtype: v.get("dtype")?.as_str()?.to_string() })
    }
}

/// The static (baked) configuration of a sweep artifact — must mirror
/// `python/compile/model.py::ModelConfig`.
#[derive(Clone, Debug)]
pub struct StaticCfg {
    pub n_base: usize,
    pub n_layers: usize,
    pub max_degree: usize,
    pub n_colors: usize,
    pub sweeps_per_call: usize,
}

impl StaticCfg {
    pub fn n_spins(&self) -> usize {
        self.n_base * self.n_layers
    }

    pub fn phases_per_sweep(&self) -> usize {
        2 * self.n_colors
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            n_base: v.get("n_base")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            max_degree: v.get("max_degree")?.as_usize()?,
            n_colors: v.get("n_colors")?.as_usize()?,
            sweeps_per_call: v.get("sweeps_per_call")?.as_usize()?,
        })
    }
}

/// Sidecar metadata of one artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// `"b1_naive"` or `"b2_coalesced"`.
    pub variant: String,
    pub config: String,
    pub static_cfg: StaticCfg,
    pub inputs: Vec<TensorSig>,
    pub n_outputs: usize,
    pub hlo_file: String,
    pub hlo_bytes: usize,
}

impl ArtifactMeta {
    pub fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            variant: v.get("variant")?.as_str()?.to_string(),
            config: v.get("config")?.as_str()?.to_string(),
            static_cfg: StaticCfg::from_json(v.get("static")?)?,
            inputs: v
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSig::from_json)
                .collect::<Result<Vec<_>>>()?,
            n_outputs: v.get("n_outputs")?.as_usize()?,
            hlo_file: v.get("hlo_file")?.as_str()?.to_string(),
            hlo_bytes: v.get("hlo_bytes")?.as_usize()?,
        })
    }
}

/// The manifest written by `make artifacts`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?} (run `make artifacts`): {e}"))?;
        Self::parse(&text).map_err(|e| anyhow::anyhow!("malformed manifest {path:?}: {e}"))
    }

    pub fn parse(text: &str) -> Result<Self> {
        let v = Value::parse(text)?;
        let artifacts = v
            .get("artifacts")?
            .as_arr()?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { artifacts })
    }

    /// Find an artifact by name (e.g. `"b2_coalesced_default"`).
    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name).ok_or_else(|| {
            let have: Vec<&str> = self.artifacts.iter().map(|a| a.name.as_str()).collect();
            anyhow::anyhow!("artifact {name:?} not in manifest (have {have:?})")
        })
    }
}

/// Default artifacts directory: `$REPRO_ARTIFACTS` or the nearest
/// ancestor `artifacts/` containing a manifest (so tests and benches work
/// from any subdirectory).
pub fn default_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("REPRO_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SIDECAR: &str = r#"{
        "name": "b2_coalesced_default", "variant": "b2_coalesced",
        "config": "default",
        "static": {"n_base": 64, "n_layers": 32, "max_degree": 4,
                    "n_colors": 2, "sweeps_per_call": 10},
        "inputs": [{"shape": [64, 32], "dtype": "float32"},
                    {"shape": [], "dtype": "int32"}],
        "n_outputs": 6, "hlo_file": "x.hlo.txt", "hlo_bytes": 10
    }"#;

    #[test]
    fn sidecar_parses() {
        let v = Value::parse(SIDECAR).unwrap();
        let m = ArtifactMeta::from_json(&v).unwrap();
        assert_eq!(m.static_cfg.n_spins(), 2048);
        assert_eq!(m.static_cfg.phases_per_sweep(), 4);
        assert_eq!(m.inputs[0].element_count(), 2048);
        assert_eq!(m.inputs[1].element_count(), 1); // scalar
    }

    #[test]
    fn manifest_lookup_errors_are_descriptive() {
        let man = Manifest::parse(&format!(r#"{{"artifacts": [{SIDECAR}]}}"#)).unwrap();
        assert!(man.get("b2_coalesced_default").is_ok());
        let err = man.get("nope").unwrap_err().to_string();
        assert!(err.contains("nope") && err.contains("b2_coalesced_default"));
    }

    #[test]
    fn missing_fields_error() {
        assert!(Manifest::parse(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
        assert!(Manifest::parse(r#"{}"#).is_err());
    }
}
