//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `python/compile/aot.py` and executes them from the rust hot path.
//!
//! This runtime is optional: the accel rungs themselves run on the
//! in-process software device ([`crate::device`]) with no artifacts or
//! PJRT installation.  Load a `Runtime` only to execute the real
//! compiled XLA kernels (`repro artifacts-check`).
//!
//! Interchange is HLO *text* (jax ≥ 0.5 emits 64-bit instruction ids in
//! serialized protos, which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids).  Python never runs at request time: `make artifacts`
//! is the only compile step, and this module is self-contained afterwards.
//!
//! * [`artifact`] — manifest / sidecar metadata, shape validation;
//! * [`client`]   — PJRT CPU client wrapper;
//! * [`executor`] — compiled executable + typed input marshalling.

pub mod artifact;
pub mod client;
pub mod executor;

pub use artifact::{ArtifactMeta, Manifest, StaticCfg, TensorSig};
pub use client::Runtime;
pub use executor::Executor;
