//! A compiled sweep executable with typed input marshalling and shape
//! validation against the artifact sidecar.

use crate::Result;

use super::artifact::{ArtifactMeta, TensorSig};

/// Host-side tensor value matching one artifact input slot.
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
    U32(&'a [u32]),
}

impl Input<'_> {
    fn dtype(&self) -> &'static str {
        match self {
            Input::F32(_) => "float32",
            Input::I32(_) => "int32",
            Input::U32(_) => "uint32",
        }
    }

    fn len(&self) -> usize {
        match self {
            Input::F32(v) => v.len(),
            Input::I32(v) => v.len(),
            Input::U32(v) => v.len(),
        }
    }

    fn to_literal(&self, sig: &TensorSig) -> Result<xla::Literal> {
        let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
        let flat = match self {
            Input::F32(v) => xla::Literal::vec1(v),
            Input::I32(v) => xla::Literal::vec1(v),
            Input::U32(v) => xla::Literal::vec1(v),
        };
        if sig.shape.is_empty() {
            // rank-0: reshape a 1-element vector to scalar
            flat.reshape(&[]).map_err(|e| anyhow::anyhow!("scalar reshape: {e}"))
        } else {
            flat.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape to {dims:?}: {e}"))
        }
    }
}

/// A compiled artifact ready to execute.
pub struct Executor {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
}

impl Executor {
    pub(crate) fn new(exe: xla::PjRtLoadedExecutable, meta: ArtifactMeta) -> Self {
        Self { exe, meta }
    }

    /// Validate inputs against the sidecar signature, execute, and return
    /// the flattened output tuple as literals.
    pub fn execute(&self, inputs: &[Input<'_>]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            anyhow::bail!(
                "artifact {} expects {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (slot, (inp, sig)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            if inp.dtype() != sig.dtype {
                anyhow::bail!(
                    "artifact {} input {slot}: dtype {} != expected {}",
                    self.meta.name,
                    inp.dtype(),
                    sig.dtype
                );
            }
            if inp.len() != sig.element_count() {
                anyhow::bail!(
                    "artifact {} input {slot}: {} elements != expected {} (shape {:?})",
                    self.meta.name,
                    inp.len(),
                    sig.element_count(),
                    sig.shape
                );
            }
            literals.push(inp.to_literal(sig)?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", self.meta.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result of {}: {e}", self.meta.name))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple result of {}: {e}", self.meta.name))?;
        if outs.len() != self.meta.n_outputs {
            anyhow::bail!(
                "artifact {} returned {} outputs, sidecar says {}",
                self.meta.name,
                outs.len(),
                self.meta.n_outputs
            );
        }
        Ok(outs)
    }
}
