//! PJRT client wrapper: compile HLO-text artifacts on the CPU device.

use std::path::Path;

use crate::Result;

use super::artifact::{ArtifactMeta, Manifest};
use super::executor::Executor;

/// A PJRT client plus artifact-loading conveniences.  One `Runtime` per
/// process (the accelerator analogue of "the GPU"); executables created
/// from it share the device.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// CPU PJRT client (the backend the interpret-mode Pallas artifacts
    /// target in this environment).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load + compile one artifact by name from a directory containing
    /// `manifest.json` (see [`super::artifact::default_dir`]).
    pub fn load_artifact(&self, dir: &Path, name: &str) -> Result<Executor> {
        let manifest = Manifest::load(dir)?;
        let meta = manifest.get(name)?.clone();
        self.compile_meta(dir, meta)
    }

    /// Compile an artifact whose metadata is already known.
    pub fn compile_meta(&self, dir: &Path, meta: ArtifactMeta) -> Result<Executor> {
        let hlo_path = dir.join(&meta.hlo_file);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path {hlo_path:?}"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse HLO {hlo_path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile artifact {}: {e}", meta.name))?;
        Ok(Executor::new(exe, meta))
    }
}
