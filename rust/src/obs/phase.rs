//! Per-phase sweep timers: RNG generation vs. spin update vs. energy
//! reduction, behind the `phase-timers` cargo feature so the hot loops
//! pay **zero** cost when disabled (the guard is a unit struct and the
//! calls compile away).
//!
//! The paper's ablation ladder separates exactly these costs (explicit
//! RNG vectorization, explicit update vectorization, reduction width),
//! so the serving tier should be able to attribute live time the same
//! way.  Instrumentation points are chosen where the phases are
//! *naturally blocked* — MT19937 block regeneration for `rng`, whole
//! sweep loops for `update`, energy recomputation for `reduce` — so an
//! enabled guard still costs one `Instant::now()` pair per *block*,
//! never per spin.  `update` is the wall time of the sweep loop and
//! therefore **includes** any `rng` block regeneration triggered inside
//! it: exclusive update time is `update - rng`.  (See DESIGN.md
//! "Observability".)
//!
//! Totals are global (per process): phase time is a property of the
//! sweep kernels, not of one service instance, and the kernels have no
//! handle to thread context through.  `snapshot()` returns `None` when
//! the feature is off, so surfaces can distinguish "disabled" from
//! "zero".

/// The three attributed sweep phases.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Phase {
    /// MT19937 block regeneration (the paper's "RNG generation" cost).
    Rng,
    /// Metropolis sweep loops (includes nested RNG regeneration).
    Update,
    /// Energy recomputation / reductions.
    Reduce,
}

/// Cumulative per-phase nanoseconds (`None` from [`snapshot`] when the
/// feature is disabled).
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct PhaseTotals {
    pub rng_ns: u64,
    pub update_ns: u64,
    pub reduce_ns: u64,
}

#[cfg(feature = "phase-timers")]
mod imp {
    use super::{Phase, PhaseTotals};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::Instant;

    static RNG_NS: AtomicU64 = AtomicU64::new(0);
    static UPDATE_NS: AtomicU64 = AtomicU64::new(0);
    static REDUCE_NS: AtomicU64 = AtomicU64::new(0);

    fn slot(phase: Phase) -> &'static AtomicU64 {
        match phase {
            Phase::Rng => &RNG_NS,
            Phase::Update => &UPDATE_NS,
            Phase::Reduce => &REDUCE_NS,
        }
    }

    /// RAII guard: accumulates the elapsed time into its phase on drop.
    pub struct PhaseGuard {
        phase: Phase,
        t0: Instant,
    }

    impl Drop for PhaseGuard {
        fn drop(&mut self) {
            slot(self.phase).fetch_add(self.t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }

    pub fn timed(phase: Phase) -> PhaseGuard {
        PhaseGuard { phase, t0: Instant::now() }
    }

    pub fn snapshot() -> Option<PhaseTotals> {
        Some(PhaseTotals {
            rng_ns: RNG_NS.load(Ordering::Relaxed),
            update_ns: UPDATE_NS.load(Ordering::Relaxed),
            reduce_ns: REDUCE_NS.load(Ordering::Relaxed),
        })
    }
}

#[cfg(not(feature = "phase-timers"))]
mod imp {
    use super::{Phase, PhaseTotals};

    /// Zero-sized no-op guard: constructing and dropping it compiles to
    /// nothing.
    pub struct PhaseGuard;

    #[inline(always)]
    pub fn timed(_phase: Phase) -> PhaseGuard {
        PhaseGuard
    }

    #[inline(always)]
    pub fn snapshot() -> Option<PhaseTotals> {
        None
    }
}

pub use imp::{snapshot, timed, PhaseGuard};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_is_free_when_disabled_and_counts_when_enabled() {
        {
            let _g = timed(Phase::Update);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        match snapshot() {
            // Feature off: the default build — no totals at all.
            None => assert!(cfg!(not(feature = "phase-timers"))),
            // Feature on: the guard above must have accumulated.
            Some(t) => {
                assert!(cfg!(feature = "phase-timers"));
                assert!(t.update_ns >= 1_000_000, "guard recorded the sleep: {t:?}");
            }
        }
    }
}
