//! Fixed-bucket log2 latency histograms with atomic recording and
//! mergeable snapshots.
//!
//! The recording path is one `fetch_add` per bucket hit — no locks, no
//! allocation — so connection threads, the scheduler and pool workers
//! can all record into the same histogram while `{"op":"stats"}` /
//! `{"op":"metrics"}` snapshot it concurrently.  A snapshot's `count` is
//! *derived* as the sum of its bucket reads (never read from a separate
//! counter), so the bucket-sum == count invariant holds by construction
//! even mid-update — the concurrency test in `tests/obs_histogram.rs`
//! hammers exactly this.
//!
//! Values are recorded in integer microseconds.  Bucket `i` holds values
//! `v` with `2^(i-1) < v <= 2^i` (bucket 0 holds `v <= 1`), i.e. the
//! Prometheus `le` edge of bucket `i` is `2^i` µs; the last bucket is
//! `+Inf`.  40 buckets span 1 µs .. ~76 h — every latency this service
//! can produce.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::{self, Value};
use crate::Result;

/// Bucket count: upper edges `2^0 .. 2^38` µs, plus a final `+Inf`.
pub const BUCKETS: usize = 40;

/// A lock-free log2 latency histogram (microsecond domain).
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    /// Sum of recorded values (µs) — feeds the mean and the Prometheus
    /// `_sum` series.  Read separately from the buckets, so it may lag
    /// a concurrent snapshot by a few in-flight records; `count` never
    /// does (it is derived from the buckets themselves).
    sum_us: AtomicU64,
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: std::array::from_fn(|_| AtomicU64::new(0)), sum_us: AtomicU64::new(0) }
    }

    /// Bucket index of a value: `v <= 2^i` with the smallest such `i`.
    pub fn bucket_index(value_us: u64) -> usize {
        if value_us <= 1 {
            0
        } else {
            (64 - (value_us - 1).leading_zeros() as usize).min(BUCKETS - 1)
        }
    }

    /// Upper edge (µs) of bucket `i`; `u64::MAX` stands in for `+Inf`.
    pub fn bucket_edge_us(i: usize) -> u64 {
        if i >= BUCKETS - 1 {
            u64::MAX
        } else {
            1u64 << i
        }
    }

    /// Record one value (µs).  Lock-free: one relaxed `fetch_add` per
    /// call plus the running sum.
    pub fn record(&self, value_us: u64) {
        self.buckets[Self::bucket_index(value_us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(value_us, Ordering::Relaxed);
    }

    /// Consistent-enough snapshot: each bucket is read once; the total
    /// is the sum of those reads.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (out, b) in buckets.iter_mut().zip(self.buckets.iter()) {
            *out = b.load(Ordering::Relaxed);
        }
        HistogramSnapshot { buckets, sum_us: self.sum_us.load(Ordering::Relaxed) }
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// A point-in-time copy of a [`Histogram`]: plain integers, mergeable,
/// quantile-queryable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub buckets: [u64; BUCKETS],
    pub sum_us: u64,
}

impl HistogramSnapshot {
    pub fn empty() -> Self {
        Self { buckets: [0; BUCKETS], sum_us: 0 }
    }

    /// Total recorded values — derived from the buckets, so it always
    /// equals their sum (the invariant the concurrency tests assert).
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Mean recorded value in µs (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us as f64 / n as f64
        }
    }

    /// Merge another snapshot into this one (shard aggregation).
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.sum_us += other.sum_us;
    }

    /// Quantile estimate in µs by linear interpolation inside the
    /// covering bucket (`q` in [0, 1]; 0 when empty).  Exact to within
    /// one log2 bucket — plenty for p50/p90/p99 serving summaries.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = q.clamp(0.0, 1.0) * n as f64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c;
            if (next as f64) >= rank {
                let lo = if i == 0 { 0.0 } else { Histogram::bucket_edge_us(i - 1) as f64 };
                // The +Inf bucket has no finite upper edge; extrapolate
                // one octave past its lower edge.
                let hi = if i >= BUCKETS - 1 { lo * 2.0 } else { Histogram::bucket_edge_us(i) as f64 };
                let within = ((rank - cum as f64) / c as f64).clamp(0.0, 1.0);
                return lo + within * (hi - lo);
            }
            cum = next;
        }
        Histogram::bucket_edge_us(BUCKETS - 2) as f64
    }

    /// The serving summary triple (p50, p90, p99) in µs.
    pub fn percentiles_us(&self) -> (f64, f64, f64) {
        (self.quantile_us(0.50), self.quantile_us(0.90), self.quantile_us(0.99))
    }

    /// Wire form for cluster aggregation: `{"sum_us": N, "buckets":
    /// [[i, count], ...]}` with zero buckets omitted (sparse — most of
    /// the 40 log2 buckets are empty for any real latency stream).  A
    /// router merges worker snapshots bucketwise via [`Self::merge`], so
    /// cluster percentiles are *exactly* what a single instance would
    /// have reported over the combined stream.
    pub fn to_value(&self) -> Value {
        let buckets: Vec<Value> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Value::Arr(vec![json::num(i as f64), json::num(c as f64)]))
            .collect();
        json::obj(vec![("sum_us", json::num(self.sum_us as f64)), ("buckets", Value::Arr(buckets))])
    }

    /// Parse the sparse wire form back; out-of-range bucket indices are
    /// an error (a peer speaking a different bucket layout must not be
    /// silently merged).
    pub fn from_value(v: &Value) -> Result<Self> {
        let mut snap = Self::empty();
        snap.sum_us = v.get("sum_us")?.as_f64()? as u64;
        for pair in v.get("buckets")?.as_arr()? {
            let pair = pair.as_arr()?;
            anyhow::ensure!(pair.len() == 2, "histogram bucket entries are [index, count] pairs");
            let i = pair[0].as_usize()?;
            anyhow::ensure!(i < BUCKETS, "bucket index {i} out of range (layout has {BUCKETS})");
            snap.buckets[i] = pair[1].as_f64()? as u64;
        }
        Ok(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_cover_the_domain() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 2);
        assert_eq!(Histogram::bucket_index(5), 3);
        assert_eq!(Histogram::bucket_index(1 << 20), 20);
        assert_eq!(Histogram::bucket_index(u64::MAX), BUCKETS - 1);
        // Every value lands in the bucket whose le-edge covers it.
        for v in [0u64, 1, 2, 7, 100, 4096, 1 << 30] {
            let i = Histogram::bucket_index(v);
            assert!(v <= Histogram::bucket_edge_us(i), "v={v} i={i}");
            if i > 0 {
                assert!(v > Histogram::bucket_edge_us(i - 1), "v={v} i={i}");
            }
        }
    }

    #[test]
    fn count_is_bucket_sum_and_quantiles_are_ordered() {
        let h = Histogram::new();
        for v in [1u64, 10, 10, 100, 1000, 10_000, 100_000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 7);
        assert_eq!(s.count(), s.buckets.iter().sum::<u64>());
        assert_eq!(s.sum_us, 111_121);
        let (p50, p90, p99) = s.percentiles_us();
        assert!(p50 <= p90 && p90 <= p99, "p50={p50} p90={p90} p99={p99}");
        assert!(p50 >= 1.0 && p99 <= 131_072.0, "p50={p50} p99={p99}");
        assert!(s.mean_us() > 0.0);
    }

    #[test]
    fn empty_snapshot_is_all_zeros() {
        let s = Histogram::new().snapshot();
        assert_eq!(s, HistogramSnapshot::empty());
        assert_eq!(s.count(), 0);
        assert_eq!(s.quantile_us(0.99), 0.0);
        assert_eq!(s.mean_us(), 0.0);
    }

    #[test]
    fn wire_form_roundtrips_sparsely() {
        let h = Histogram::new();
        for v in [1u64, 5, 5, 700, 1 << 20] {
            h.record(v);
        }
        let s = h.snapshot();
        let wire = s.to_value();
        // Sparse: only the populated buckets travel.
        assert_eq!(wire.get("buckets").unwrap().as_arr().unwrap().len(), 4);
        let back = HistogramSnapshot::from_value(&wire).unwrap();
        assert_eq!(back, s);
        // A foreign bucket layout is refused, not silently merged.
        let bogus = crate::util::json::Value::parse(r#"{"sum_us":1,"buckets":[[99,1]]}"#).unwrap();
        assert!(HistogramSnapshot::from_value(&bogus).is_err());
    }

    #[test]
    fn merge_adds_bucketwise() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(5);
        a.record(500);
        b.record(5);
        let mut s = a.snapshot();
        s.merge(&b.snapshot());
        assert_eq!(s.count(), 3);
        assert_eq!(s.sum_us, 510);
        assert_eq!(s.buckets[Histogram::bucket_index(5)], 2);
    }
}
