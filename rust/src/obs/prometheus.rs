//! Prometheus text-format exposition (version 0.0.4): `# HELP`/`# TYPE`
//! headers, `name{labels} value` samples, histogram `_bucket`/`_sum`/
//! `_count` families with cumulative `le` edges ending at `+Inf`.
//!
//! Dependency-free by design, like [`crate::util::json`]: the emitter is
//! a string builder with label escaping, shared by the `{"op":"metrics"}`
//! wire op and the `repro serve --metrics-every N` periodic snapshot.
//! Histograms are emitted in **seconds** (the Prometheus base-unit
//! convention) from the µs-domain [`HistogramSnapshot`]s.

use super::hist::{Histogram, HistogramSnapshot, BUCKETS};

/// Builder for one exposition document.  Common labels (host
/// fingerprint, git sha) are attached to every sample.
pub struct PromWriter {
    out: String,
    /// Pre-rendered common label list, e.g. `host="...",sha="..."`.
    common: String,
}

impl PromWriter {
    pub fn new(common_labels: &[(&str, &str)]) -> Self {
        Self { out: String::new(), common: render_labels(common_labels) }
    }

    /// All labels for one sample: common ∪ extra, or "" when both empty.
    fn labels(&self, extra: &[(&str, &str)]) -> String {
        let extra = render_labels(extra);
        match (self.common.is_empty(), extra.is_empty()) {
            (true, true) => String::new(),
            (false, true) => format!("{{{}}}", self.common),
            (true, false) => format!("{{{extra}}}"),
            (false, false) => format!("{{{},{extra}}}", self.common),
        }
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// One monotonically-increasing counter sample.
    pub fn counter(&mut self, name: &str, help: &str, value: u64) {
        self.header(name, help, "counter");
        let labels = self.labels(&[]);
        self.out.push_str(&format!("{name}{labels} {value}\n"));
    }

    /// One gauge sample.
    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, help, "gauge");
        let labels = self.labels(&[]);
        self.out.push_str(&format!("{name}{labels} {}\n", fmt_f64(value)));
    }

    /// A counter family: one header, many label-distinguished samples.
    pub fn counter_family(&mut self, name: &str, help: &str, samples: &[(Vec<(&str, &str)>, u64)]) {
        self.header(name, help, "counter");
        for (extra, value) in samples {
            let labels = self.labels(extra);
            self.out.push_str(&format!("{name}{labels} {value}\n"));
        }
    }

    /// One histogram family from a µs-domain snapshot, emitted in
    /// seconds with cumulative `le` buckets.
    pub fn histogram_seconds(&mut self, name: &str, help: &str, snap: &HistogramSnapshot) {
        self.header(name, help, "histogram");
        let mut cum = 0u64;
        for i in 0..BUCKETS {
            cum += snap.buckets[i];
            let le = if i == BUCKETS - 1 {
                "+Inf".to_string()
            } else {
                fmt_f64(Histogram::bucket_edge_us(i) as f64 * 1e-6)
            };
            let labels = self.labels(&[("le", &le)]);
            self.out.push_str(&format!("{name}_bucket{labels} {cum}\n"));
        }
        let labels = self.labels(&[]);
        self.out.push_str(&format!("{name}_sum{labels} {}\n", fmt_f64(snap.sum_us as f64 * 1e-6)));
        self.out.push_str(&format!("{name}_count{labels} {}\n", snap.count()));
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// `k1="v1",k2="v2"` with label-value escaping per the text format.
fn render_labels(pairs: &[(&str, &str)]) -> String {
    pairs
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect::<Vec<_>>()
        .join(",")
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Plain (non-scientific) float formatting: Prometheus parsers accept
/// exponent notation, but fixed-point keeps the checker and human eyes
/// simple.  Integers print without a fraction.
fn fmt_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        let s = format!("{v}");
        // Rust only switches to scientific notation for extreme
        // magnitudes, which the µs→s scaling here never produces.
        debug_assert!(!s.contains('e') && !s.contains('E'), "unexpected exponent in {s}");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_with_common_labels() {
        let mut w = PromWriter::new(&[("host", "x86_64 avx2=true"), ("sha", "abc123")]);
        w.counter("repro_jobs_total", "Jobs.", 7);
        w.gauge("repro_queue_depth", "Depth.", 3.0);
        let text = w.finish();
        assert!(text.contains("# HELP repro_jobs_total Jobs.\n"));
        assert!(text.contains("# TYPE repro_jobs_total counter\n"));
        assert!(text.contains(r#"repro_jobs_total{host="x86_64 avx2=true",sha="abc123"} 7"#));
        assert!(text.contains(r#"repro_queue_depth{host="x86_64 avx2=true",sha="abc123"} 3"#));
    }

    #[test]
    fn histograms_emit_cumulative_buckets_sum_and_count() {
        let h = Histogram::new();
        h.record(1); // bucket 0 (le=1e-6 s)
        h.record(3); // bucket 2 (le=4e-6 s)
        let mut w = PromWriter::new(&[]);
        w.histogram_seconds("repro_e2e_seconds", "E2E.", &h.snapshot());
        let text = w.finish();
        assert!(text.contains("# TYPE repro_e2e_seconds histogram\n"));
        assert!(text.contains(r#"repro_e2e_seconds_bucket{le="0.000001"} 1"#));
        assert!(text.contains(r#"repro_e2e_seconds_bucket{le="0.000004"} 2"#));
        assert!(text.contains(r#"repro_e2e_seconds_bucket{le="+Inf"} 2"#));
        assert!(text.contains("repro_e2e_seconds_sum 0.000004\n"));
        assert!(text.contains("repro_e2e_seconds_count 2\n"));
        // Buckets are cumulative (monotone non-decreasing in le order).
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "non-monotone bucket line: {line}");
            last = v;
        }
    }

    #[test]
    fn label_values_are_escaped() {
        let mut w = PromWriter::new(&[]);
        w.counter_family(
            "repro_lane_occupancy_total",
            "Occupancy.",
            &[(vec![("shape", "4x4x8"), ("note", "a\"b\\c")], 5)],
        );
        let text = w.finish();
        assert!(text.contains(r#"shape="4x4x8""#));
        assert!(text.contains(r#"note="a\"b\\c""#));
    }
}
