//! Observability: job-lifecycle tracing, latency/lane-fill histograms,
//! windowed rates, per-phase kernel timers and Prometheus exposition —
//! the measurement layer under the serving tier and the bench harness.
//!
//! The paper's central diagnostic is the fraction of vector width
//! utilized; the ROADMAP's next control loops (w8 → w4 bucket
//! retargeting, router backpressure for sharded serving) need that
//! diagnostic as *distributions over time*, not lifetime counters.
//! This module provides the substrate:
//!
//! * [`hist`] — fixed-bucket log2 latency histograms: atomic recording,
//!   mergeable snapshots, p50/p90/p99 queries.
//! * [`trace`] — per-job stage stamps (admit → enqueue → seal →
//!   dispatch → sweep → reply) and a bounded ring of recent traces.
//! * [`rate`] — lock-free sliding-window jobs/sec and spins/sec.
//! * [`phase`] — feature-gated RNG/update/reduce kernel timers.
//! * [`prometheus`] — text-format exposition shared by
//!   `{"op":"metrics"}` and `repro serve --metrics-every N`.
//!
//! [`Obs`] aggregates one service instance's histograms, traces and
//! rates; `service::metrics::ServiceMetrics` owns one and surfaces it
//! through the wire ops.

pub mod hist;
pub mod phase;
pub mod prometheus;
pub mod rate;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

pub use hist::{Histogram, HistogramSnapshot};
pub use rate::RateWindow;
pub use trace::{JobTrace, StageTiming, Timeline, TraceRing};

/// Resolved service configuration, echoed in stats (and the
/// `{"op":"hello"}` handshake) so scrapes are self-describing (set once
/// at engine start).
#[derive(Clone, Debug)]
pub struct ConfigEcho {
    /// Negotiated lane width of the serving C-rung.
    pub lanes: usize,
    pub flush_ms: u64,
    pub max_queue: usize,
    pub threads: usize,
    /// Resolved backend label of the serving C-rung (`"avx2"`, `"sse2"`,
    /// `"portable"`, ...) — capability-aware routers place batchable
    /// work by this.
    pub backend: String,
}

/// Per-shape lane-fill histogram: how many batch dispatches of this
/// shape bucket went out with each occupancy `0..=W` — the distribution
/// behind the scalar `lane_fill_ratio` gauge, per shape, which is the
/// signal the w8 → w4 retargeting loop needs (a shape averaging 3/8
/// occupied lanes wants a narrower batch).
#[derive(Clone, Debug)]
pub struct FillSnapshot {
    pub width: usize,
    /// `counts[k]` = dispatches that carried `k` real jobs.
    pub counts: Vec<u64>,
}

impl FillSnapshot {
    pub fn dispatches(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Mean occupied-lane fraction over this shape's dispatches.
    pub fn mean_fill(&self) -> f64 {
        let n = self.dispatches();
        if n == 0 || self.width == 0 {
            return 1.0;
        }
        let occupied: u64 = self.counts.iter().enumerate().map(|(k, &c)| k as u64 * c).sum();
        occupied as f64 / (n * self.width as u64) as f64
    }
}

/// All per-shape fill histograms of one service (shape label → counts).
/// Guarded by a mutex: recording happens once per *dispatch* (not per
/// job, not per spin), so contention is negligible.
#[derive(Default)]
pub struct FillHistograms {
    inner: Mutex<BTreeMap<String, FillSnapshot>>,
}

impl FillHistograms {
    /// Record one batch dispatch of `occupancy`/`width` lanes for
    /// `shape`.
    pub fn record(&self, shape: &str, occupancy: usize, width: usize) {
        let mut g = match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let entry = g
            .entry(shape.to_string())
            .or_insert_with(|| FillSnapshot { width, counts: vec![0; width + 1] });
        let k = occupancy.min(entry.width);
        entry.counts[k] += 1;
    }

    pub fn snapshot(&self) -> BTreeMap<String, FillSnapshot> {
        match self.inner.lock() {
            Ok(g) => g.clone(),
            Err(poisoned) => poisoned.into_inner().clone(),
        }
    }
}

/// The observability surface of one running service instance.
pub struct Obs {
    /// Enqueue → batch-seal wait (how long jobs wait for lane-mates).
    pub queue_wait_us: Histogram,
    /// Sweep execution time (sweep_start → sweep_end).
    pub exec_us: Histogram,
    /// Admission → reply, the client-visible latency.
    pub e2e_us: Histogram,
    /// Sweep-pool task wall time (whole dispatches, run jobs included)
    /// — shared with the pool via `SweepPool::set_task_hist`.
    pub pool_task_us: Arc<Histogram>,
    /// Per-shape lane-fill distributions.
    pub fill: FillHistograms,
    /// Recent completed-job traces (`{"op":"trace"}`).
    pub traces: TraceRing,
    /// Completed jobs per second over the rate window.
    pub jobs_rate: RateWindow,
    /// Attempted spin updates per second over the rate window.
    pub spins_rate: RateWindow,
    /// Spin updates attempted by completed jobs (the numerator behind
    /// `spins_rate`, exposed as a lifetime counter too).
    pub spins_attempted: AtomicU64,
    started: Instant,
    started_at_ms: u64,
    config: OnceLock<ConfigEcho>,
}

impl Obs {
    pub fn new() -> Self {
        let started = Instant::now();
        let started_at_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        Self {
            queue_wait_us: Histogram::new(),
            exec_us: Histogram::new(),
            e2e_us: Histogram::new(),
            pool_task_us: Arc::new(Histogram::new()),
            fill: FillHistograms::default(),
            traces: TraceRing::new(TraceRing::DEFAULT_CAP),
            jobs_rate: RateWindow::new(started),
            spins_rate: RateWindow::new(started),
            spins_attempted: AtomicU64::new(0),
            started,
            started_at_ms,
            config: OnceLock::new(),
        }
    }

    /// Milliseconds since this instance started (serve start, not
    /// per-request).
    pub fn uptime_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// Unix epoch milliseconds of serve start.
    pub fn started_at_ms(&self) -> u64 {
        self.started_at_ms
    }

    /// Record the resolved config once at engine start (later calls are
    /// ignored — the config cannot change while serving).
    pub fn set_config(&self, echo: ConfigEcho) {
        let _ = self.config.set(echo);
    }

    pub fn config(&self) -> Option<ConfigEcho> {
        self.config.get().cloned()
    }

    /// Account one completed (ok) job: latency histograms and rates.
    pub fn record_completed(&self, timing: &StageTiming, spins_attempted: u64) {
        self.queue_wait_us.record(timing.queue_us);
        self.exec_us.record(timing.sweep_us);
        self.e2e_us.record(timing.e2e_us);
        let now = Instant::now();
        self.jobs_rate.record(1, now);
        self.spins_rate.record(spins_attempted, now);
        self.spins_attempted.fetch_add(spins_attempted, Ordering::Relaxed);
    }
}

impl Default for Obs {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_completed_feeds_every_surface() {
        let obs = Obs::new();
        let timing =
            StageTiming { queue_us: 100, sweep_us: 2000, e2e_us: 2500, ..StageTiming::default() };
        obs.record_completed(&timing, 640);
        obs.record_completed(&timing, 640);
        assert_eq!(obs.queue_wait_us.snapshot().count(), 2);
        assert_eq!(obs.exec_us.snapshot().count(), 2);
        assert_eq!(obs.e2e_us.snapshot().count(), 2);
        assert_eq!(obs.spins_attempted.load(Ordering::Relaxed), 1280);
        assert!(obs.jobs_rate.per_sec(10, Instant::now()) > 0.0);
    }

    #[test]
    fn fill_histograms_track_per_shape_occupancy() {
        let f = FillHistograms::default();
        f.record("4x4x8", 8, 8);
        f.record("4x4x8", 3, 8);
        f.record("6x6x4", 2, 8);
        let snap = f.snapshot();
        let s = &snap["4x4x8"];
        assert_eq!(s.dispatches(), 2);
        assert_eq!(s.counts[8], 1);
        assert_eq!(s.counts[3], 1);
        assert!((s.mean_fill() - 11.0 / 16.0).abs() < 1e-12);
        assert_eq!(snap["6x6x4"].dispatches(), 1);
    }

    #[test]
    fn config_echo_is_write_once() {
        let obs = Obs::new();
        assert!(obs.config().is_none());
        obs.set_config(ConfigEcho {
            lanes: 8,
            flush_ms: 25,
            max_queue: 1024,
            threads: 2,
            backend: "avx2".into(),
        });
        obs.set_config(ConfigEcho {
            lanes: 4,
            flush_ms: 1,
            max_queue: 1,
            threads: 1,
            backend: "sse2".into(),
        });
        let c = obs.config().unwrap();
        assert_eq!(c.lanes, 8, "first write wins");
        assert_eq!(c.backend, "avx2");
        assert!(obs.uptime_ms() < 60_000);
        assert!(obs.started_at_ms() > 0);
    }
}
