//! Job-lifecycle tracing: monotonic stage stamps carried on each job
//! through the serving path, folded into per-stage durations at reply
//! time, and a bounded ring of recent traces for `{"op":"trace"}`.
//!
//! The event layer is lock-free where it matters: a [`Timeline`] is
//! plain data *owned by its job* (it rides on
//! `service::batcher::PendingJob`), so stamping a stage is a field
//! store — no shared state, no atomics, no locks on the sweep path.
//! Only the final [`TraceRing::push`] (once per job, after the reply is
//! serialized) takes a short mutex on the bounded ring.
//!
//! Stage model (each duration is the gap to the previous stamp, so the
//! stages are consecutive and their sum is ≤ the end-to-end latency by
//! construction — floor rounding to whole µs only loses time, never
//! invents it):
//!
//! ```text
//! admit ─▶ enqueue ─▶ seal ─▶ dispatch ─▶ sweep_start ─▶ sweep_end ─▶ reply
//!   admit_us  queue_us  dispatch_us  setup_us    sweep_us     reply_us
//! ```

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{self, Value};
use crate::Result;

/// Monotonic stage stamps of one job's trip through the service.
/// `admit`/`enqueue` always exist (a job is created by admission);
/// later stages are stamped as the job reaches them.
#[derive(Copy, Clone, Debug)]
pub struct Timeline {
    /// Connection thread passed the admission gate.
    pub admit: Instant,
    /// Scheduler pushed the job into its shape bucket.
    pub enqueue: Instant,
    /// Batcher sealed the job into a dispatch (full batch or flush).
    pub seal: Option<Instant>,
    /// Pool worker picked the dispatch up.
    pub dispatch: Option<Instant>,
    /// Sweeping began (for batches: after lane-batch construction).
    pub sweep_start: Option<Instant>,
    /// Sweeping finished.
    pub sweep_end: Option<Instant>,
}

impl Timeline {
    pub fn new(admit: Instant, enqueue: Instant) -> Self {
        Self { admit, enqueue, seal: None, dispatch: None, sweep_start: None, sweep_end: None }
    }

    /// Fold the stamps into per-stage durations, ending at `reply` (the
    /// moment the result line is serialized).  A missing stamp
    /// contributes a zero-length stage (its duration folds into the
    /// next), keeping the consecutive-intervals invariant.
    pub fn stages(&self, reply: Instant) -> StageTiming {
        let us = |a: Instant, b: Instant| b.saturating_duration_since(a).as_micros() as u64;
        let seal = self.seal.unwrap_or(self.enqueue);
        let dispatch = self.dispatch.unwrap_or(seal);
        let sweep_start = self.sweep_start.unwrap_or(dispatch);
        let sweep_end = self.sweep_end.unwrap_or(sweep_start);
        StageTiming {
            admit_us: us(self.admit, self.enqueue),
            queue_us: us(self.enqueue, seal),
            dispatch_us: us(seal, dispatch),
            setup_us: us(dispatch, sweep_start),
            sweep_us: us(sweep_start, sweep_end),
            reply_us: us(sweep_end, reply),
            e2e_us: us(self.admit, reply),
        }
    }
}

/// Per-stage durations (µs) of one completed job — the `"timing"`
/// object a `"want_timing":true` job gets echoed on the wire.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct StageTiming {
    /// Admission gate → scheduler enqueue (channel hand-off).
    pub admit_us: u64,
    /// Enqueue → batch seal (waiting for lane-mates).
    pub queue_us: u64,
    /// Seal → pool pickup (dispatch hand-off).
    pub dispatch_us: u64,
    /// Pickup → sweeping (lane-batch/model construction).
    pub setup_us: u64,
    /// The sweeps themselves.
    pub sweep_us: u64,
    /// Sweep end → result serialization.
    pub reply_us: u64,
    /// Admission → result serialization (≥ the sum of the stages).
    pub e2e_us: u64,
}

impl StageTiming {
    /// Sum of the consecutive stages — ≤ [`Self::e2e_us`] by
    /// construction (each stage floors to whole µs independently).
    pub fn stage_sum_us(&self) -> u64 {
        self.admit_us
            + self.queue_us
            + self.dispatch_us
            + self.setup_us
            + self.sweep_us
            + self.reply_us
    }

    pub fn to_value(&self) -> Value {
        json::obj(vec![
            ("admit_us", json::num(self.admit_us as f64)),
            ("queue_us", json::num(self.queue_us as f64)),
            ("dispatch_us", json::num(self.dispatch_us as f64)),
            ("setup_us", json::num(self.setup_us as f64)),
            ("sweep_us", json::num(self.sweep_us as f64)),
            ("reply_us", json::num(self.reply_us as f64)),
            ("e2e_us", json::num(self.e2e_us as f64)),
        ])
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let us = |key: &str| -> Result<u64> { Ok(v.get(key)?.as_usize()? as u64) };
        Ok(Self {
            admit_us: us("admit_us")?,
            queue_us: us("queue_us")?,
            dispatch_us: us("dispatch_us")?,
            setup_us: us("setup_us")?,
            sweep_us: us("sweep_us")?,
            reply_us: us("reply_us")?,
            e2e_us: us("e2e_us")?,
        })
    }
}

/// One completed job's trace as kept in the ring (and returned by
/// `{"op":"trace"}`).
#[derive(Clone, Debug)]
pub struct JobTrace {
    /// Completion sequence number (monotonic per service).
    pub seq: u64,
    pub id: String,
    /// Shape-bucket label (`WxHxL`) or `"run"` for run jobs.
    pub shape: String,
    /// Rung that served the job (`C.1w8`, `A.2`, `M.1`, `run`).
    pub kind: String,
    pub ok: bool,
    pub timing: StageTiming,
}

impl JobTrace {
    pub fn to_value(&self) -> Value {
        json::obj(vec![
            ("seq", json::num(self.seq as f64)),
            ("id", json::str_v(&self.id)),
            ("shape", json::str_v(&self.shape)),
            ("kind", json::str_v(&self.kind)),
            ("ok", Value::Bool(self.ok)),
            ("timing", self.timing.to_value()),
        ])
    }
}

/// Bounded in-memory ring of the most recent job traces.  Pushed once
/// per completed job (off the sweep hot path); the mutex guards a
/// VecDeque rotation and is never held across I/O.
pub struct TraceRing {
    cap: usize,
    inner: Mutex<RingState>,
}

struct RingState {
    next_seq: u64,
    traces: VecDeque<JobTrace>,
}

impl TraceRing {
    /// Traces kept by the service (the `{"op":"trace"}` depth bound).
    pub const DEFAULT_CAP: usize = 256;

    pub fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            inner: Mutex::new(RingState { next_seq: 0, traces: VecDeque::new() }),
        }
    }

    /// Append one trace (assigning its sequence number), evicting the
    /// oldest past capacity.
    pub fn push(&self, mut trace: JobTrace) {
        let mut g = match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        trace.seq = g.next_seq;
        g.next_seq += 1;
        if g.traces.len() == self.cap {
            g.traces.pop_front();
        }
        g.traces.push_back(trace);
    }

    /// The most recent `n` traces, oldest first.
    pub fn recent(&self, n: usize) -> Vec<JobTrace> {
        let g = match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let skip = g.traces.len().saturating_sub(n);
        g.traces.iter().skip(skip).cloned().collect()
    }

    /// Total traces ever pushed (≥ the ring's current length).
    pub fn pushed(&self) -> u64 {
        match self.inner.lock() {
            Ok(g) => g.next_seq,
            Err(poisoned) => poisoned.into_inner().next_seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn stage_sum_never_exceeds_e2e() {
        let t0 = Instant::now();
        let tl = Timeline {
            admit: t0,
            enqueue: t0 + Duration::from_micros(3),
            seal: Some(t0 + Duration::from_micros(1500)),
            dispatch: Some(t0 + Duration::from_micros(1517)),
            sweep_start: Some(t0 + Duration::from_micros(1619)),
            sweep_end: Some(t0 + Duration::from_micros(9_997)),
        };
        let s = tl.stages(t0 + Duration::from_micros(10_010));
        assert_eq!(s.admit_us, 3);
        assert_eq!(s.queue_us, 1497);
        assert_eq!(s.dispatch_us, 17);
        assert_eq!(s.setup_us, 102);
        assert_eq!(s.sweep_us, 8378);
        assert_eq!(s.reply_us, 13);
        assert_eq!(s.e2e_us, 10_010);
        assert!(s.stage_sum_us() <= s.e2e_us);
    }

    #[test]
    fn missing_stamps_fold_into_zero_length_stages() {
        let t0 = Instant::now();
        let tl = Timeline::new(t0, t0);
        let s = tl.stages(t0 + Duration::from_micros(50));
        assert_eq!(s.queue_us, 0);
        assert_eq!(s.sweep_us, 0);
        assert_eq!(s.reply_us, 50);
        assert_eq!(s.e2e_us, 50);
        assert!(s.stage_sum_us() <= s.e2e_us);
    }

    #[test]
    fn timing_roundtrips_through_json() {
        let s = StageTiming {
            admit_us: 1,
            queue_us: 2,
            dispatch_us: 3,
            setup_us: 4,
            sweep_us: 5,
            reply_us: 6,
            e2e_us: 30,
        };
        let back = StageTiming::from_value(&s.to_value()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn ring_is_bounded_and_fifo() {
        let ring = TraceRing::new(3);
        for i in 0..5 {
            ring.push(JobTrace {
                seq: 0,
                id: format!("j{i}"),
                shape: "4x4x8".into(),
                kind: "C.1w8".into(),
                ok: true,
                timing: StageTiming::default(),
            });
        }
        assert_eq!(ring.pushed(), 5);
        let recent = ring.recent(10);
        assert_eq!(recent.len(), 3, "capacity bound");
        assert_eq!(recent[0].id, "j2");
        assert_eq!(recent[2].id, "j4");
        assert_eq!(recent[2].seq, 4, "sequence numbers are assigned in push order");
        assert_eq!(ring.recent(1).len(), 1);
        assert_eq!(ring.recent(1)[0].id, "j4");
    }
}
