//! Windowed rate tracking: jobs/sec and spins/sec over the last N
//! seconds, so throughput is observable live rather than only as a
//! lifetime average.
//!
//! The tracker is a ring of per-second slots, each an `(epoch_second,
//! count)` atomic pair indexed by `second % SLOTS`.  Recording is
//! lock-free: load the slot's epoch tag, CAS it forward if the slot is
//! stale (the winner zeroes the count), then `fetch_add`.  A racing
//! recorder can in principle add to a slot between the winner's CAS and
//! its zeroing store — the loss is bounded by the in-flight records of
//! one slot turnover and only perturbs a *rate gauge*, never a counter,
//! so the trade is taken for the lock-freedom.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Ring size: must exceed the largest window queried (the service asks
/// for [`RateWindow::WINDOW_SECS`]) by enough slack that a slot is never
/// reused while still inside the window.
const SLOTS: usize = 64;

/// Lock-free sliding-window event-rate tracker.
pub struct RateWindow {
    start: Instant,
    /// Per-slot epoch tag: `second + 1` of the counts currently stored
    /// there (0 = never used).
    tags: [AtomicU64; SLOTS],
    counts: [AtomicU64; SLOTS],
}

impl RateWindow {
    /// The window the service reports rates over.
    pub const WINDOW_SECS: u64 = 10;

    pub fn new(start: Instant) -> Self {
        Self {
            start,
            tags: std::array::from_fn(|_| AtomicU64::new(0)),
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    fn second(&self, now: Instant) -> u64 {
        now.saturating_duration_since(self.start).as_secs()
    }

    /// Record `n` events at `now`.
    pub fn record(&self, n: u64, now: Instant) {
        let sec = self.second(now);
        let slot = (sec % SLOTS as u64) as usize;
        let tag = sec + 1;
        let mut cur = self.tags[slot].load(Ordering::Acquire);
        while cur != tag {
            match self.tags[slot].compare_exchange_weak(cur, tag, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => {
                    // This thread turned the slot over to the new second.
                    self.counts[slot].store(0, Ordering::Release);
                    break;
                }
                Err(seen) => {
                    if seen > tag {
                        // A racing recorder already advanced the slot past
                        // our second (we slept across a turnover): the
                        // event belongs to a second that has left the
                        // ring — drop it rather than pollute a live slot.
                        return;
                    }
                    cur = seen;
                }
            }
        }
        self.counts[slot].fetch_add(n, Ordering::AcqRel);
    }

    /// Events per second over the trailing `window_secs` full seconds
    /// ending at `now` (the current partial second included).
    pub fn per_sec(&self, window_secs: u64, now: Instant) -> f64 {
        let window = window_secs.clamp(1, SLOTS as u64 - 1);
        let sec = self.second(now);
        let lo = sec.saturating_sub(window - 1);
        let mut total = 0u64;
        for s in lo..=sec {
            let slot = (s % SLOTS as u64) as usize;
            if self.tags[slot].load(Ordering::Acquire) == s + 1 {
                total += self.counts[slot].load(Ordering::Acquire);
            }
        }
        // Normalize by the elapsed window, not the nominal one, so early
        // scrapes (uptime < window) are not artificially deflated.
        let elapsed = (sec - lo) as f64 + 1.0;
        total as f64 / elapsed.min(window as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn rate_counts_recent_seconds_only() {
        let t0 = Instant::now();
        let r = RateWindow::new(t0);
        for s in 0..5u64 {
            r.record(10, t0 + Duration::from_secs(s));
        }
        // At t=4 the trailing 5 seconds hold all 50 events.
        assert_eq!(r.per_sec(5, t0 + Duration::from_secs(4)), 10.0);
        // Far in the future every slot is stale (or reused and re-tagged).
        assert_eq!(r.per_sec(5, t0 + Duration::from_secs(1000)), 0.0);
    }

    #[test]
    fn slot_reuse_resets_old_counts() {
        let t0 = Instant::now();
        let r = RateWindow::new(t0);
        r.record(100, t0);
        // Same ring slot, SLOTS seconds later: the tag CAS must zero it.
        let later = t0 + Duration::from_secs(SLOTS as u64);
        r.record(7, later);
        assert_eq!(r.per_sec(1, later), 7.0);
    }

    #[test]
    fn early_scrapes_normalize_by_elapsed_time() {
        let t0 = Instant::now();
        let r = RateWindow::new(t0);
        r.record(30, t0);
        // 30 events in the first second; a 10 s window must not report 3.
        assert_eq!(r.per_sec(10, t0), 30.0);
    }

    #[test]
    fn concurrent_recording_is_close_to_exact_within_one_second() {
        let t0 = Instant::now();
        let r = std::sync::Arc::new(RateWindow::new(t0));
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let r = std::sync::Arc::clone(&r);
                std::thread::spawn(move || {
                    for _ in 0..10_000 {
                        r.record(1, t0);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        // All records hit one already-tagged slot: no turnover race, so
        // the count is exact.
        assert_eq!(r.per_sec(1, t0), 40_000.0);
    }
}
