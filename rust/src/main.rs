//! `repro` — CLI for the explicit-vectorization reproduction.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts:
//!
//! ```text
//! repro run        --kind a4-full-w8 ...  # full PT simulation + report
//! repro table1                            # implementation matrix
//! repro table2     [--opt0-bin PATH]      # pairwise speedups (+ Fig 15)
//! repro fig13      [--accel]              # ladder x threads (+ B.1/B.2)
//! repro fig14                             # wait-probability curves
//! repro fig17                             # exp approximation error
//! repro bench-rung --kind ... --json      # timing probe (used across build profiles)
//! repro bench      [--json] [--check]     # BENCH_<rung>.json artifacts + perf gate
//! repro artifacts-check                   # load + execute every artifact once
//! ```
//!
//! Workload flags (shared by most subcommands):
//! `--width 8 --height 8 --layers 32 --models 8 --sweeps 200
//!  --sweeps-per-round 10 --threads 1 --seed 1 --paper-scale`

use std::path::PathBuf;
use std::str::FromStr;

use vectorising::coordinator::{self, Checkpoint, RunConfig, RunOptions, RunSpec};
use vectorising::engine::{EngineBuilder, Rung, SamplerSpec, UnsupportedGeometry, Width};
use vectorising::harness::bench::{self, BenchArtifact};
use vectorising::harness::{fig13, fig14, fig17, table1, table2};
use vectorising::ising::builder::torus_workload;
use vectorising::router;
use vectorising::runtime::{artifact, Runtime};
use vectorising::service;
use vectorising::service::executor::Executor;
use vectorising::service::job::{JobResult, Request};
use vectorising::sweep::accel::{AccelSweeper, AccelVariant};
use vectorising::sweep::{ExpMode, SweepKind, Sweeper};
use vectorising::util::cli::Args;
use vectorising::Result;

const USAGE: &str = "\
repro — reproduction of 'Importance of Explicit Vectorization for CPU and GPU Software Performance'

USAGE: repro <subcommand> [flags]

SUBCOMMANDS
  run              full parallel-tempering simulation (--json)
                   sampler spec: --rung a1|a2|a3|a4|c1|m1|b1|b2
                                 [--width auto|4|8|16|64]
                                 [--backend auto|sse2|avx2|avx512|portable]
                   (with --rung, torus dims use --torus-width/--torus-height)
                   legacy spellings still work: --kind a1..a4 | a3-vec-rng-w8
                          | a4-full-w8 | c1-replica-batch[-w8] | b1 | b2
                   (default: rung a4, width auto — the widest lane count the
                    host + layer count support; rung c1 sweeps one replica
                    per SIMD lane and accepts any layers >= 2; rung m1
                    bit-packs 64 layers per word — width is fixed at 64,
                    the workload is the ±1-coupling family, any even
                    layers >= 2; rungs b1/b2 execute on the in-process
                    software device — 32-thread warps over the host
                    vector units with counted coalesced/strided memory
                    transactions; b1 needs layers >= 2, b2 even
                    layers >= 2 — and are bit-exact to scalar a2)
                   checkpointing (schema v2, spec-carrying):
                     --checkpoint PATH        save atomically during the run
                     --checkpoint-every N     rounds between saves (default 1;
                                              the final round always saves)
                     --resume PATH            rebuild + restore from a saved
                                              checkpoint — the sampler comes
                                              from the file, no flags needed
                                              (--sweeps/--threads may override)
  plan             print the capability-negotiated Plan as JSON without
                   running: --rung ... [--width ...] [--backend ...]
                   [--layers N] (e.g. `repro plan --rung c1 --width auto
                   --layers 2` explains why the A-rungs were rejected)
  table1           implementation matrix (paper Table 1)
  table2           pairwise CPU speedups, 1 core (paper Table 2 + Fig 15)
                   [--opt0-bin target/opt0/repro | --skip-opt0] [--csv PATH]
  fig13            ladder x thread-counts (+ --accel for B.1/B.2) [--csv PATH]
  fig14            wait-probability curves per replica [--csv PATH]
  fig17            exponential approximation error [--csv PATH]
  bench-rung       timing probe for one rung (--kind ..., --json)
  bench            machine-readable bench artifacts + perf gate: measures
                   --rungs m1,c1w8,b1,b2 (default; entries take a wN
                   suffix, e.g. a4w8) on the paper's per-model geometry
                   (12x8x256 spins); --json prints one artifact line per
                   rung; --out DIR writes BENCH_<rung>.json files;
                   --check gates the run (m1 must hold >= 3x C.1w8
                   spins/sec, the coalesced device rung b2 >= 2x b1;
                   same-host measured baselines from --baseline-dir
                   (default bench/) gate a 10% regression) and exits 1
                   on failure
  artifacts-check  load + execute every artifact once
  serve            sampling service (protocol_version 1): JSON-lines jobs in,
                   per-job results out (each echoing the resolved plan),
                   dynamically lane-batched onto the C-rungs (jobs that
                   pin rung m1 run as bit-packed singles)
                   [--listen HOST:PORT | stdin/stdout]
                   [--lanes 4|8|16] [--backend auto|sse2|avx2|avx512|portable]
                   [--threads N] [--flush-ms N] [--exact]
                   [--max-queue N]  admission cap: over-cap jobs are
                   refused with {"error":"overloaded","retry_after_ms":..}
                   (default 1024, 0 = unbounded)
                   [--metrics-every N]  Prometheus text snapshot to stderr
                   every N seconds (0 = off, the default)
  route            shard router: the same protocol as serve, fronting N
                   workers — jobs consistent-hash by (rung class, shape)
                   bucket onto a worker ring so every worker's batcher
                   sees deep same-shape buckets; each bucket is served
                   by --replicas workers (least-in-flight wins, overload
                   fails over; only when all replicas refuse does the
                   client see the merged rejection); worker death replays
                   in-flight jobs onto survivors (seeded jobs are
                   bit-exact anywhere, so zero admitted jobs are lost);
                   stats/metrics/trace/hello answer cluster-wide (exact
                   histogram merges, per-worker Prometheus labels)
                   --listen HOST:PORT, then one of:
                     --spawn N      boot N local workers (owned: they
                                    shut down with the router; serving
                                    flags --lanes/--backend/--threads/
                                    --flush-ms/--max-queue/--exact are
                                    forwarded to them)
                     --workers a,b  front an existing fleet
                   [--replicas N]   workers per bucket (default 2)
                   [--health-ms N]  probe period (default 500)
  submit           client for a serving instance: --addr HOST:PORT
                   [--file jobs.jsonl | stdin] [--stats] [--metrics]
                   [--trace] [--shutdown]
  job-run          run job lines directly on the scalar A.2 reference
                   [--file jobs.jsonl | stdin] [--exact]
                   (the bit-exactness oracle for C-rung served results;
                   m1-pinned lines run the multi-spin path instead)

WORKLOAD FLAGS (run/table2/fig13/fig14/bench-rung)
  --width N --height N   torus dims (default 8x8); with --rung use
  --torus-width N --torus-height N   (since --width is the lane count there)
  --layers N             QMC layers (default 32; multiple of 4)
  --models N             tempering replicas (default 8)
  --sweeps N             sweeps per replica (default 200)
  --sweeps-per-round N   sweeps between exchanges (default 10)
  --threads N            worker threads (default 1)
  --seed N               workload seed (default 1)
  --paper-scale          paper geometry: 96x256 spins, 115 models, 30000 sweeps
";

/// Parse the sampler spec flags: `--rung/--width/--backend` (the v1
/// surface) or the legacy `--kind` spelling, which lowers onto a spec.
/// `None` when neither is given (the caller picks its default).
fn sampler_spec_args(a: &Args) -> Result<Option<SamplerSpec>> {
    if let Some(r) = a.str_opt("rung") {
        anyhow::ensure!(
            a.str_opt("kind").is_none(),
            "--kind and --rung are mutually exclusive (use --rung {} --width ...)",
            r
        );
        let mut spec = SamplerSpec::rung(Rung::from_str(r)?);
        if let Some(w) = a.str_opt("width") {
            spec.width = w.parse()?;
        }
        if let Some(b) = a.str_opt("backend") {
            spec.backend = b.parse()?;
        }
        return Ok(Some(spec));
    }
    if let Some(k) = a.str_opt("kind") {
        let mut spec = SweepKind::from_str(k)?.spec();
        // --backend composes with legacy kinds (--width stays the torus
        // dimension there, as it always was).
        if let Some(b) = a.str_opt("backend") {
            spec.backend = b.parse()?;
        }
        return Ok(Some(spec));
    }
    Ok(None)
}

fn workload_config(a: &Args) -> Result<RunConfig> {
    if a.switch("paper-scale") {
        let mut c = RunConfig::paper();
        c.threads = a.usize_or("threads", 1)?;
        c.seed = a.u64_or("seed", 1)?;
        return Ok(c);
    }
    // With --rung, --width is the lane count, so the torus width moves
    // to --torus-width (accepted in legacy mode too).  --height never
    // clashes with a spec axis and is always honored.
    let spec_mode = a.str_opt("rung").is_some();
    let torus_width = if a.str_opt("torus-width").is_some() {
        a.usize_or("torus-width", 8)?
    } else if spec_mode {
        8
    } else {
        a.usize_or("width", 8)?
    };
    let torus_height = if a.str_opt("torus-height").is_some() {
        a.usize_or("torus-height", 8)?
    } else {
        a.usize_or("height", 8)?
    };
    Ok(RunConfig {
        width: torus_width,
        height: torus_height,
        layers: a.usize_or("layers", 32)?,
        n_models: a.usize_or("models", 8)?,
        sweeps: a.usize_or("sweeps", 200)?,
        sweeps_per_round: a.usize_or("sweeps-per-round", 10)?,
        threads: a.usize_or("threads", 1)?,
        beta_cold: a.f32_or("beta-cold", 3.0)?,
        beta_hot: a.f32_or("beta-hot", 0.5)?,
        jtau: a.f32_or("jtau", 0.3)?,
        seed: a.u64_or("seed", 1)?,
    })
}

fn csv_path(a: &Args) -> Option<PathBuf> {
    a.str_opt("csv").map(PathBuf::from)
}

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let sub = match args.subcommand.as_deref() {
        Some(s) => s.to_string(),
        None => {
            print!("{USAGE}");
            return Ok(());
        }
    };
    match sub.as_str() {
        "run" => {
            let opts = RunOptions {
                checkpoint: args.str_opt("checkpoint").map(PathBuf::from),
                checkpoint_every: args.usize_or("checkpoint-every", 1)?,
                resume: None,
            };
            let (cfg, spec, opts) = if let Some(resume_path) = args.str_opt("resume") {
                // Resume is spec-driven: the checkpoint carries the whole
                // RunSpec (v1 files lower their kind label); only sweeps
                // and threads may be overridden from the command line.
                let ck = Checkpoint::load(&PathBuf::from(resume_path))?;
                let mut rs = ck.run_spec()?;
                if args.str_opt("sweeps").is_some() {
                    rs.config.sweeps = args.usize_or("sweeps", rs.config.sweeps)?;
                }
                if args.str_opt("threads").is_some() {
                    rs.config.threads = args.usize_or("threads", rs.config.threads)?;
                }
                let opts = RunOptions { resume: Some(ck), ..opts };
                (rs.config, rs.sampler, opts)
            } else {
                let cfg = workload_config(&args)?;
                // Default: rung a4, width auto — the widest lane count this
                // host has a backend for (AVX2 octets when detected, SSE
                // quadruplets else), narrowed to what the layer count
                // supports.
                let spec = sampler_spec_args(&args)?
                    .unwrap_or_else(|| SweepKind::preferred_cpu_for_layers(cfg.layers).spec());
                (cfg, spec, opts)
            };
            // Every rung — including the B-rungs, which execute on the
            // in-process software device with a host-resident scalar
            // MT19937 — goes through the coordinator, so checkpointing
            // and bit-exact resume work uniformly.
            let outcome = coordinator::run_spec_with(&RunSpec::new(cfg.clone(), spec), &opts);
            let report = match outcome {
                Ok(report) => report,
                Err(e) => {
                    // Structured geometry rejections carry ready-to-run
                    // alternative specs — print the best one.
                    if let Some(ug) = e.downcast_ref::<UnsupportedGeometry>() {
                        eprintln!("error: {ug}");
                        if let Some(alt) = ug.alternatives.first() {
                            eprintln!("try: repro run {} --layers {}", alt.cli(), cfg.layers);
                        }
                        std::process::exit(2);
                    }
                    return Err(e);
                }
            };
            if args.switch("json") {
                println!("{}", report.to_json());
            } else {
                println!(
                    "{} | {} models x {} sweeps x {} spins | threads={}",
                    report.kind,
                    report.n_models,
                    report.sweeps,
                    cfg.n_spins_per_model(),
                    report.threads
                );
                println!(
                    "wall {:.3}s | {:.2}M updates/s | flip rate {:.4} | swap acc {:.3}",
                    report.wall_seconds,
                    report.updates_per_sec / 1e6,
                    report.total_flips as f64 / report.total_attempts.max(1) as f64,
                    report.swap_acceptance
                );
                for (i, (p, e)) in report.flip_probs.iter().zip(&report.energies).enumerate() {
                    println!("  model {i:3}  P(flip)={p:.4}  E={e:.2}");
                }
            }
        }
        "plan" => {
            // Geometry is just the layer count; torus dims are irrelevant
            // to negotiation, so `--width auto` here is the lane width.
            let layers = args.usize_or("layers", 32)?;
            let spec = sampler_spec_args(&args)?
                .unwrap_or_else(|| SamplerSpec::rung(Rung::A4));
            match EngineBuilder::new(spec).layers(layers).plan() {
                Ok(plan) => println!("{}", plan.to_json()),
                Err(e) => {
                    eprintln!("error: {e:#}");
                    if let Some(ug) = e.downcast_ref::<UnsupportedGeometry>() {
                        if let Some(alt) = ug.alternatives.first() {
                            eprintln!("try: repro plan {} --layers {layers}", alt.cli());
                        }
                    }
                    std::process::exit(2);
                }
            }
        }
        "table1" => print!("{}", table1::render()),
        "table2" => {
            let cfg = workload_config(&args)?;
            eprintln!("measuring optimized rungs (A.1b, A.2b, A.3/A.4 at the host widths, M.1)...");
            let mut rungs = table2::measure_optimized(&cfg)?;
            if !args.switch("skip-opt0") {
                let opt0_bin = PathBuf::from(args.str_or("opt0-bin", "target/opt0/repro"));
                if opt0_bin.exists() {
                    eprintln!("measuring opt0 rungs (A.1a, A.2a) via {opt0_bin:?}...");
                    let mut un = table2::measure_unoptimized(&cfg, &opt0_bin)?;
                    un.append(&mut rungs);
                    rungs = un;
                } else {
                    eprintln!(
                        "note: {opt0_bin:?} not found — build it with `make opt0` for the A.1a/A.2a rows"
                    );
                }
            }
            print!("{}", table2::render(&rungs, csv_path(&args).as_deref())?);
        }
        "fig13" => {
            let cfg = workload_config(&args)?;
            let counts = args.usize_list_or("thread-counts", &[1, 2, 4, 6, 8])?;
            let rows = fig13::compute(&cfg, &counts, args.switch("accel"))?;
            print!("{}", fig13::render(&rows, csv_path(&args).as_deref())?);
        }
        "fig14" => {
            let cfg = workload_config(&args)?;
            print!("{}", fig14::run(&cfg, csv_path(&args).as_deref())?);
        }
        "fig17" => print!("{}", fig17::run(csv_path(&args).as_deref())?),
        "bench-rung" => {
            let cfg = workload_config(&args)?;
            let spec = sampler_spec_args(&args)?
                .ok_or_else(|| anyhow::anyhow!("--kind or --rung required"))?;
            let t = coordinator::time_sweeps(&cfg, spec)?;
            if args.switch("json") {
                println!("{}", t.to_json());
            } else {
                println!(
                    "{} threads={} {:.3}s ({:.2}M updates/s){}",
                    t.kind,
                    t.threads,
                    t.seconds,
                    t.updates_per_sec / 1e6,
                    if t.opt_disabled { " [opt0]" } else { "" }
                );
            }
        }
        "bench" => {
            // Acceptance geometry: the paper's per-model torus
            // (12x8x256 = 24,576 spins), small sweep counts — the point
            // is a stable throughput sample, not equilibration.
            let cfg = RunConfig {
                width: args.usize_or("torus-width", 12)?,
                height: args.usize_or("torus-height", 8)?,
                layers: args.usize_or("layers", 256)?,
                n_models: args.usize_or("models", 8)?,
                sweeps: args.usize_or("sweeps", 40)?,
                sweeps_per_round: args.usize_or("sweeps-per-round", 20)?,
                threads: args.usize_or("threads", 1)?,
                beta_cold: args.f32_or("beta-cold", 3.0)?,
                beta_hot: args.f32_or("beta-hot", 0.5)?,
                jtau: args.f32_or("jtau", 0.5)?,
                seed: args.u64_or("seed", 1)?,
            };
            let specs = bench_specs(&args.str_or("rungs", "m1,c1w8,b1,b2"))?;
            let mut artifacts = Vec::new();
            for spec in specs {
                let art = BenchArtifact::measure(&RunSpec::new(cfg.clone(), spec))?;
                if args.switch("json") {
                    println!("{}", art.to_json());
                } else {
                    println!(
                        "{:8} {:8.1}M spins/s  lane fill {:.2}  ({}x{}x{}, {} models, \
                         {} sweeps, threads={})",
                        art.rung,
                        art.spins_per_sec / 1e6,
                        art.lane_fill,
                        art.torus_width,
                        art.torus_height,
                        art.layers,
                        art.n_models,
                        art.sweeps,
                        art.threads
                    );
                }
                artifacts.push(art);
            }
            if let Some(dir) = args.str_opt("out") {
                for art in &artifacts {
                    let path = art.write_to(std::path::Path::new(dir))?;
                    eprintln!("wrote {}", path.display());
                }
            }
            if args.switch("check") {
                let dir = PathBuf::from(args.str_or("baseline-dir", "bench"));
                let outcome = bench::gate(&artifacts, &bench::load_dir(&dir)?);
                for line in &outcome.lines {
                    println!("{line}");
                }
                if !outcome.passed() {
                    eprintln!("perf gate FAILED ({} failure(s))", outcome.failures.len());
                    std::process::exit(1);
                }
                println!("perf gate passed");
            }
        }
        "artifacts-check" => {
            let dir = args.str_opt("dir").map(PathBuf::from).unwrap_or_else(artifact::default_dir);
            let rt = Runtime::cpu()?;
            let manifest = artifact::Manifest::load(&dir)?;
            println!("platform: {} ({} devices)", rt.platform_name(), rt.device_count());
            for meta in &manifest.artifacts {
                let cfg = &meta.static_cfg;
                let (w, h) = factor_torus(cfg.n_base);
                let wl = torus_workload(w, h, cfg.n_layers, 1, 0.3);
                let variant = if meta.variant.starts_with("b1") {
                    AccelVariant::B1Naive
                } else {
                    AccelVariant::B2Coalesced
                };
                let mut sw = AccelSweeper::new(&rt, &dir, &meta.config, variant, &wl, 5489)?;
                let stats = sw.run(cfg.sweeps_per_call, 0.5);
                let consistency = sw.validate();
                println!(
                    "  {:24} OK: {} sweeps, {} flips, |E_artifact - E_host| = {:.3e}",
                    meta.name, cfg.sweeps_per_call, stats.flips, consistency
                );
            }
        }
        "serve" => {
            let cfg = service::ServiceConfig {
                lanes: args.usize_or("lanes", vectorising::simd::widest_supported_width())?,
                backend: args.str_or("backend", "auto").parse()?,
                threads: args.usize_or("threads", 1)?,
                flush_ms: args.u64_or("flush-ms", 25)?,
                exp: if args.switch("exact") { ExpMode::Exact } else { ExpMode::Fast },
                max_queue: args.usize_or("max-queue", 1024)?,
                metrics_every_secs: args.u64_or("metrics-every", 0)?,
            };
            match args.str_opt("listen") {
                Some(addr) => {
                    let listener = std::net::TcpListener::bind(addr)?;
                    eprintln!(
                        "repro serve: listening on {} (W={}, threads={}, flush={}ms, max-queue={})",
                        listener.local_addr()?,
                        cfg.lanes,
                        cfg.threads,
                        cfg.flush_ms,
                        cfg.max_queue
                    );
                    service::server::serve_tcp(listener, &cfg)?;
                }
                None => service::server::serve_stdin(&cfg)?,
            }
        }
        "route" => {
            let listen = args
                .str_opt("listen")
                .ok_or_else(|| anyhow::anyhow!("--listen HOST:PORT required"))?;
            let cfg = router::RouterConfig {
                replicas: args.usize_or("replicas", 2)?,
                health_ms: args.u64_or("health-ms", 500)?,
            };
            let spawn_n = args.usize_or("spawn", 0)?;
            let explicit: Vec<String> = args
                .str_opt("workers")
                .map(|list| {
                    list.split(',').map(|a| a.trim().to_string()).filter(|a| !a.is_empty()).collect()
                })
                .unwrap_or_default();
            anyhow::ensure!(
                (spawn_n > 0) != (!explicit.is_empty()),
                "pass exactly one of --spawn N or --workers HOST:PORT,..."
            );
            let owned = if spawn_n > 0 {
                // Forward serving flags verbatim to the spawned workers.
                let mut serve_flags: Vec<String> = Vec::new();
                for flag in ["lanes", "backend", "threads", "flush-ms", "max-queue"] {
                    if let Some(v) = args.str_opt(flag) {
                        serve_flags.push(format!("--{flag}"));
                        serve_flags.push(v.to_string());
                    }
                }
                if args.switch("exact") {
                    serve_flags.push("--exact".to_string());
                }
                router::spawn_workers(spawn_n, &serve_flags)?
            } else {
                Vec::new()
            };
            let worker_addrs: Vec<String> = if owned.is_empty() {
                explicit
            } else {
                owned.iter().map(|w| w.addr.clone()).collect()
            };
            let listener = std::net::TcpListener::bind(listen)?;
            eprintln!(
                "repro route: listening on {} ({} workers, replicas={}, health={}ms)",
                listener.local_addr()?,
                worker_addrs.len(),
                cfg.replicas,
                cfg.health_ms
            );
            let served = router::serve(listener, &worker_addrs, &cfg);
            // Owned workers shut down with the router (a standalone
            // fleet passed via --workers keeps serving).
            router::shutdown_workers(owned);
            served?;
        }
        "submit" => {
            let addr = args
                .str_opt("addr")
                .ok_or_else(|| anyhow::anyhow!("--addr HOST:PORT required"))?;
            let mut out = std::io::stdout();
            let lines = if args.switch("shutdown") {
                vec!["{\"op\":\"shutdown\"}".to_string()]
            } else if args.switch("stats") {
                vec!["{\"op\":\"stats\"}".to_string()]
            } else if args.switch("metrics") {
                vec!["{\"op\":\"metrics\"}".to_string()]
            } else if args.switch("trace") {
                vec!["{\"op\":\"trace\"}".to_string()]
            } else {
                read_request_lines(args.str_opt("file"))?
            };
            service::server::submit_lines(addr, lines, &mut out)?;
        }
        "job-run" => {
            let exp = if args.switch("exact") { ExpMode::Exact } else { ExpMode::Fast };
            let exec = Executor::new(4, exp)?; // lane width is irrelevant for the scalar path
            for line in read_request_lines(args.str_opt("file"))? {
                let out_line = match service::job::parse_request(&line) {
                    Ok(Request::Job(spec)) => match exec.run_single(&spec) {
                        Ok(result) => result.to_line(),
                        Err(e) => JobResult::error_line(&spec.id, &format!("{e:#}")),
                    },
                    Ok(_) => continue, // control ops have no direct-run meaning
                    Err(e) => JobResult::error_line("", &format!("{e:#}")),
                };
                println!("{out_line}");
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprintln!("unknown subcommand {other:?}\n");
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

/// Parse the `--rungs` list of the bench subcommand: comma-separated
/// rung spellings, each with an optional `w<N>` width suffix (`m1`,
/// `c1w8`, `a4w16`, ...).
fn bench_specs(list: &str) -> Result<Vec<SamplerSpec>> {
    list.split(',')
        .map(|entry| {
            let entry = entry.trim();
            anyhow::ensure!(!entry.is_empty(), "empty entry in --rungs list");
            let (head, width) = match entry.rfind('w') {
                Some(i)
                    if i > 0
                        && entry.len() > i + 1
                        && entry[i + 1..].bytes().all(|b| b.is_ascii_digit()) =>
                {
                    (&entry[..i], Some(entry[i + 1..].parse::<usize>()?))
                }
                _ => (entry, None),
            };
            let mut spec = SamplerSpec::rung(Rung::from_str(head.trim_end_matches('-'))?);
            if let Some(w) = width {
                spec.width = Width::W(w);
            }
            Ok(spec)
        })
        .collect()
}

/// Request lines for submit/job-run: from `--file PATH` or stdin.
fn read_request_lines(path: Option<&str>) -> Result<Vec<String>> {
    let text = match path {
        Some(p) => std::fs::read_to_string(p)?,
        None => {
            use std::io::Read as _;
            let mut s = String::new();
            std::io::stdin().read_to_string(&mut s)?;
            s
        }
    };
    Ok(text.lines().map(|l| l.trim().to_string()).filter(|l| !l.is_empty()).collect())
}

/// Factor n into the most square even-by-even torus (for artifacts-check).
fn factor_torus(n: usize) -> (usize, usize) {
    let mut best = (n, 1);
    for w in 2..=n {
        if n % w == 0 {
            let h = n / w;
            if w % 2 == 0 && h % 2 == 0 && w >= h && (w - h) < (best.0 - best.1) {
                best = (w, h);
            }
        }
    }
    best
}
