//! Plain-text table and CSV output helpers for the harness.

use std::io::Write;
use std::path::Path;

use crate::Result;

/// A simple aligned text table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (c, h) in self.header.iter().enumerate() {
            width[c] = h.len();
        }
        for row in &self.rows {
            for (c, cell) in row.iter().enumerate() {
                width[c] = width[c].max(cell.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (c, cell) in cells.iter().enumerate() {
                if c > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>w$}", cell, w = width[c]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
        }
        out
    }

    /// Write as CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// Format a float with fixed precision (harness convention: 3 decimals
/// for ratios, like the paper's Table 2).
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a float with 4 decimals (probabilities).
pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new(vec!["a", "long_header"]);
        t.row(vec!["1", "2"]);
        t.row(vec!["100", "20000"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("long_header"));
        // all rows same width
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1"]);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new(vec!["x", "y"]);
        t.row(vec!["1", "2"]);
        let dir = std::env::temp_dir().join("vectorising_test_csv");
        let path = dir.join("t.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "x,y\n1,2\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
