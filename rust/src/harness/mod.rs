//! Benchmark harness — regenerates every table and figure of the paper's
//! evaluation (§4).  Each submodule owns one artifact:
//!
//! | Module | Paper artifact |
//! |--------|----------------|
//! | [`table1`] | Table 1 — the implementation matrix |
//! | [`fig13`]  | Fig 13 — relative performance, rungs × threads + B.1/B.2 |
//! | [`table2`] | Table 2 — pairwise speedups A.1a…A.4 on 1 core (+ Fig 15) |
//! | [`fig14`]  | Fig 14 — P(wait for a flip) per tempering replica |
//! | [`fig17`]  | Fig 17 — relative error of the exp approximations |
//!
//! Output is an aligned text table on stdout plus (optionally) CSV files
//! under `results/`, so plots can be regenerated offline.  The [`bench`]
//! module is the machine-readable side: `BENCH_<rung>.json` artifacts
//! (spins/sec, lane fill, host caps, git sha) and the perf gate CI runs
//! on them.

pub mod bench;
pub mod fig13;
pub mod fig14;
pub mod fig17;
pub mod report;
pub mod table1;
pub mod table2;
