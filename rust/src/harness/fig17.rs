//! Fig 17 — relative error of the fast and accurate exponential
//! approximations as a function of the input.
//!
//! The paper plots the pointwise relative error over the valid input
//! range; the harness reports per-bucket min/max/mean relative error for
//! both variants (plus a CSV suitable for plotting), and checks the
//! headline bounds: fast in roughly (−4%, +2%), accurate in
//! (−1%, +0.5%).

use std::path::Path;

use crate::expapprox::{exp_accurate, exp_fast, ACCURATE_LO, FAST_HI, FAST_LO};
use crate::Result;

use super::report::{f4, Table};

/// One bucket of the error sweep.
#[derive(Clone, Debug)]
pub struct Bucket {
    pub x_lo: f64,
    pub x_hi: f64,
    pub fast_min: f64,
    pub fast_max: f64,
    pub acc_min: f64,
    pub acc_max: f64,
}

/// Sweep the error curves over `[lo, hi)` with `samples` points in
/// `buckets` buckets.  `lo`/`hi` default to the accurate variant's
/// domain (the paper's Fig-17 x-range is −20…20).
pub fn sweep(lo: f64, hi: f64, samples: usize, buckets: usize) -> Vec<Bucket> {
    assert!(hi > lo && buckets > 0 && samples >= buckets);
    let mut out: Vec<Bucket> = (0..buckets)
        .map(|b| {
            let w = (hi - lo) / buckets as f64;
            Bucket {
                x_lo: lo + w * b as f64,
                x_hi: lo + w * (b + 1) as f64,
                fast_min: f64::INFINITY,
                fast_max: f64::NEG_INFINITY,
                acc_min: f64::INFINITY,
                acc_max: f64::NEG_INFINITY,
            }
        })
        .collect();
    let step = (hi - lo) / samples as f64;
    for i in 0..samples {
        let x = lo + step * (i as f64 + 0.5);
        let exact = x.exp();
        let b = ((x - lo) / (hi - lo) * buckets as f64) as usize;
        let b = b.min(buckets - 1);
        if x > FAST_LO as f64 && x < FAST_HI as f64 {
            let rf = exp_fast(x as f32) as f64 / exact - 1.0;
            out[b].fast_min = out[b].fast_min.min(rf);
            out[b].fast_max = out[b].fast_max.max(rf);
        }
        // Accurate variant is exactly 0 below its domain; relative error
        // is only meaningful inside it.
        if x > ACCURATE_LO as f64 {
            let ra = exp_accurate(x as f32) as f64 / exact - 1.0;
            // For x >= 0 the paper clamps to >= 1.0 (accept threshold);
            // error there reflects the clamp, still reported.
            out[b].acc_min = out[b].acc_min.min(ra);
            out[b].acc_max = out[b].acc_max.max(ra);
        }
    }
    out
}

/// Render Fig 17 as a table; write CSV if `csv` is given.
pub fn run(csv: Option<&Path>) -> Result<String> {
    let buckets = sweep(-20.0, 20.0, 400_000, 20);
    let mut t = Table::new(vec!["x range", "fast min", "fast max", "accurate min", "accurate max"]);
    for b in &buckets {
        t.row(vec![
            format!("[{:6.1},{:6.1})", b.x_lo, b.x_hi),
            f4(b.fast_min),
            f4(b.fast_max),
            f4(b.acc_min),
            f4(b.acc_max),
        ]);
    }
    if let Some(path) = csv {
        t.write_csv(path)?;
    }
    let fast_min = buckets.iter().map(|b| b.fast_min).fold(f64::INFINITY, f64::min);
    let fast_max = buckets.iter().map(|b| b.fast_max).fold(f64::NEG_INFINITY, f64::max);
    let acc_min = buckets
        .iter()
        .filter(|b| b.x_hi <= 0.0)
        .map(|b| b.acc_min)
        .fold(f64::INFINITY, f64::min);
    let acc_max = buckets
        .iter()
        .filter(|b| b.x_hi <= 0.0)
        .map(|b| b.acc_max)
        .fold(f64::NEG_INFINITY, f64::max);
    Ok(format!(
        "{}\noverall: fast ({:.4}, {:.4})  [paper: ~(-0.04, +0.02)]\n         accurate ({:.4}, {:.4}) over x<0  [paper: ~(-0.01, +0.005)]\n",
        t.render(),
        fast_min,
        fast_max,
        acc_min,
        acc_max
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_reproduces_paper_bounds() {
        let buckets = sweep(-20.0, 10.0, 100_000, 10);
        let fmin = buckets.iter().map(|b| b.fast_min).fold(f64::INFINITY, f64::min);
        let fmax = buckets.iter().map(|b| b.fast_max).fold(f64::NEG_INFINITY, f64::max);
        assert!(fmin > -0.040 && fmin < -0.030, "fast min {fmin}");
        assert!(fmax < 0.0205 && fmax > 0.015, "fast max {fmax}");
        let neg: Vec<&Bucket> = buckets.iter().filter(|b| b.x_hi <= 0.0).collect();
        let amin = neg.iter().map(|b| b.acc_min).fold(f64::INFINITY, f64::min);
        let amax = neg.iter().map(|b| b.acc_max).fold(f64::NEG_INFINITY, f64::max);
        assert!(amin > -0.0101, "accurate min {amin}");
        assert!(amax < 0.0051, "accurate max {amax}");
    }

    #[test]
    fn run_renders() {
        let s = run(None).unwrap();
        assert!(s.contains("fast"));
        assert!(s.contains("accurate"));
    }
}
