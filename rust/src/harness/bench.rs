//! Machine-readable bench artifacts (`BENCH_<rung>.json`) and the perf
//! regression gate built on them.
//!
//! Every timing the harness or the benches publish is serialized as one
//! [`BenchArtifact`]: throughput (spins/sec), lane geometry (width and
//! fill), the host's vector capabilities, the git revision and a
//! `provenance` marker (`"measured"` on the emitting host,
//! `"estimate"` for hand-seeded baselines awaiting a refresh).  The
//! artifacts are the bench trajectory of the repo — CI re-measures and
//! gates on them instead of eyeballing bench stdout.
//!
//! The gate ([`gate`]) enforces two things:
//!
//! * **within-run ratio** — the multi-spin rung must retire at least
//!   [`MIN_M1_OVER_C1W8`]× the spins/sec of the `C.1w8` lane-batch
//!   measured in the same run, and the coalesced device rung `B.2` at
//!   least [`MIN_B2_OVER_B1`]× the naive `B.1` (host-independent,
//!   always checked when both sides are present);
//! * **absolute regression** — a rung must stay within
//!   [`MAX_REGRESSION`] of its committed baseline, but only when the
//!   baseline is `"measured"` on a host with the same capability
//!   fingerprint and thread count (cross-host absolute numbers are
//!   noise, so mismatches downgrade to a note, never a failure).

use std::path::{Path, PathBuf};

use crate::coordinator::{self, LatencyPercentiles, RunConfig, RunSpec};
use crate::engine::Rung;
use crate::simd;
use crate::util::json::{self, Value};
use crate::Result;

/// Bumped when the artifact layout changes incompatibly.
pub const BENCH_SCHEMA_VERSION: usize = 1;

/// Minimum m1-over-C.1w8 throughput ratio the gate demands.
pub const MIN_M1_OVER_C1W8: f64 = 3.0;

/// Minimum B.2-over-B.1 throughput ratio the gate demands — the paper's
/// coalescing speedup, reproduced on the software device: the coalesced
/// layout's contiguous SoA loads must beat the naive layout's strided
/// AoS gathers by at least this factor in the same run.
pub const MIN_B2_OVER_B1: f64 = 2.0;

/// Maximum tolerated slowdown against a same-host measured baseline.
pub const MAX_REGRESSION: f64 = 0.10;

/// Vector capabilities of the measuring host — absolute numbers are only
/// comparable between identical fingerprints.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HostCaps {
    pub avx2: bool,
    pub avx512: bool,
    /// Widest lane count the legacy width negotiation resolves to.
    pub widest_rng_width: usize,
}

impl HostCaps {
    pub fn detect() -> Self {
        Self {
            avx2: simd::avx2_available(),
            avx512: simd::avx512_available(),
            widest_rng_width: simd::widest_supported_width(),
        }
    }

    /// Equality key for "are absolute numbers comparable".
    pub fn fingerprint(&self) -> String {
        format!(
            "{} avx2={} avx512={} rngw={}",
            std::env::consts::ARCH,
            self.avx2,
            self.avx512,
            self.widest_rng_width
        )
    }

    fn to_value(&self) -> Value {
        json::obj(vec![
            ("arch", json::str_v(std::env::consts::ARCH)),
            ("avx2", Value::Bool(self.avx2)),
            ("avx512", Value::Bool(self.avx512)),
            ("widest_rng_width", json::num(self.widest_rng_width as f64)),
        ])
    }

    fn from_value(v: &Value) -> Result<Self> {
        Ok(Self {
            avx2: v.get("avx2")?.as_bool()?,
            avx512: v.get("avx512")?.as_bool()?,
            widest_rng_width: v.get("widest_rng_width")?.as_usize()?,
        })
    }
}

/// One machine-readable bench measurement.
#[derive(Clone, Debug)]
pub struct BenchArtifact {
    pub schema: usize,
    /// Resolved plan label, e.g. `M.1`, `C.1w8`, `A.4w16`.
    pub rung: String,
    pub threads: usize,
    pub sweeps: usize,
    pub seconds: f64,
    /// Spin-update attempts per second across all replicas and threads.
    pub spins_per_sec: f64,
    /// Negotiated lane count (64 bit-lanes for m1, SIMD lanes else).
    pub lane_width: usize,
    /// Fraction of lane slots carrying real work (m1 pads the last word
    /// of each layer column; C-rungs pad the tail replica batch).
    pub lane_fill: f64,
    pub torus_width: usize,
    pub torus_height: usize,
    pub layers: usize,
    pub n_models: usize,
    pub host: HostCaps,
    /// `git rev-parse` of the emitting checkout (`unknown` outside git).
    pub git_sha: String,
    /// `"measured"` when emitted by a real run on this host;
    /// `"estimate"` for hand-seeded baselines (never gated absolutely).
    pub provenance: String,
    /// Per-round sweep wall-time percentiles (µs) from the timing run —
    /// the tail behaviour behind the mean throughput (`None` in legacy
    /// artifacts; the gate ignores it, CI plots it).
    pub round_latency: Option<LatencyPercentiles>,
}

impl BenchArtifact {
    /// Measure one spec through the coordinator's timing path and wrap
    /// the result as a `"measured"` artifact.
    pub fn measure(rs: &RunSpec) -> Result<Self> {
        let plan = rs.plan()?;
        let t = coordinator::time_sweeps_spec(rs)?;
        let cfg = &rs.config;
        Ok(Self {
            schema: BENCH_SCHEMA_VERSION,
            rung: plan.label(),
            threads: t.threads,
            sweeps: t.sweeps,
            seconds: t.seconds,
            spins_per_sec: t.updates_per_sec,
            lane_width: plan.width,
            lane_fill: lane_fill(rs.sampler.rung, plan.width, cfg),
            torus_width: cfg.width,
            torus_height: cfg.height,
            layers: cfg.layers,
            n_models: cfg.n_models,
            host: HostCaps::detect(),
            git_sha: git_sha(),
            provenance: "measured".into(),
            round_latency: t.round_latency,
        })
    }

    /// `BENCH_<rung>.json` — the rung label lowercased with the dots
    /// dropped (`M.1` → `BENCH_m1.json`, `C.1w8` → `BENCH_c1w8.json`).
    pub fn file_name(rung_label: &str) -> String {
        format!("BENCH_{}.json", rung_label.to_ascii_lowercase().replace('.', ""))
    }

    /// Write the artifact into `dir` under its canonical file name.
    pub fn write_to(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(Self::file_name(&self.rung));
        std::fs::write(&path, format!("{}\n", self.to_json()))?;
        Ok(path)
    }

    pub fn to_value(&self) -> Value {
        let mut fields = vec![
            ("schema", json::num(self.schema as f64)),
            ("rung", json::str_v(&self.rung)),
            ("threads", json::num(self.threads as f64)),
            ("sweeps", json::num(self.sweeps as f64)),
            ("seconds", json::num(self.seconds)),
            ("spins_per_sec", json::num(self.spins_per_sec)),
            ("lane_width", json::num(self.lane_width as f64)),
            ("lane_fill", json::num(self.lane_fill)),
            ("torus_width", json::num(self.torus_width as f64)),
            ("torus_height", json::num(self.torus_height as f64)),
            ("layers", json::num(self.layers as f64)),
            ("n_models", json::num(self.n_models as f64)),
            ("host", self.host.to_value()),
            ("git_sha", json::str_v(&self.git_sha)),
            ("provenance", json::str_v(&self.provenance)),
        ];
        if let Some(p) = self.round_latency {
            fields.push(("round_p50_us", json::num(p.p50_us)));
            fields.push(("round_p90_us", json::num(p.p90_us)));
            fields.push(("round_p99_us", json::num(p.p99_us)));
        }
        json::obj(fields)
    }

    pub fn to_json(&self) -> String {
        self.to_value().to_string()
    }

    pub fn from_value(v: &Value) -> Result<Self> {
        let schema = v.get("schema")?.as_usize()?;
        anyhow::ensure!(
            schema <= BENCH_SCHEMA_VERSION,
            "bench artifact schema {schema} is newer than this build speaks \
             ({BENCH_SCHEMA_VERSION})"
        );
        Ok(Self {
            schema,
            rung: v.get("rung")?.as_str()?.to_string(),
            threads: v.get("threads")?.as_usize()?,
            sweeps: v.get("sweeps")?.as_usize()?,
            seconds: v.get("seconds")?.as_f64()?,
            spins_per_sec: v.get("spins_per_sec")?.as_f64()?,
            lane_width: v.get("lane_width")?.as_usize()?,
            lane_fill: v.get("lane_fill")?.as_f64()?,
            torus_width: v.get("torus_width")?.as_usize()?,
            torus_height: v.get("torus_height")?.as_usize()?,
            layers: v.get("layers")?.as_usize()?,
            n_models: v.get("n_models")?.as_usize()?,
            host: HostCaps::from_value(v.get("host")?)?,
            git_sha: v.get("git_sha")?.as_str()?.to_string(),
            provenance: v.get("provenance")?.as_str()?.to_string(),
            round_latency: LatencyPercentiles::from_round_fields(v)?,
        })
    }

    pub fn from_json(text: &str) -> Result<Self> {
        Self::from_value(&Value::parse(text)?)
    }
}

/// Fraction of lane slots carrying real work for a resolved rung on a
/// given workload shape.
pub fn lane_fill(rung: Rung, width: usize, cfg: &RunConfig) -> f64 {
    if rung.is_multispin() {
        let nw = cfg.layers.div_ceil(64);
        cfg.layers as f64 / (64 * nw) as f64
    } else if rung.is_replica_batch() {
        let batches = cfg.n_models.div_ceil(width);
        cfg.n_models as f64 / (width * batches) as f64
    } else {
        // The A-rungs negotiate a width the layer count divides, and the
        // scalar/accel paths have no lanes to pad.
        1.0
    }
}

/// Git revision of the working tree, `unknown` when not in a checkout
/// (the artifact stays valid — provenance is what the gate trusts).
pub fn git_sha() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .output()
        .ok()
        .filter(|out| out.status.success())
        .and_then(|out| String::from_utf8(out.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Load every `BENCH_*.json` under `dir` (missing dir → empty set).
pub fn load_dir(dir: &Path) -> Result<Vec<BenchArtifact>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(out),
    };
    for entry in entries {
        let path = entry?.path();
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with("BENCH_") && name.ends_with(".json") {
            let text = std::fs::read_to_string(&path)?;
            out.push(
                BenchArtifact::from_json(&text)
                    .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?,
            );
        }
    }
    out.sort_by(|a, b| a.rung.cmp(&b.rung));
    Ok(out)
}

/// Outcome of one gate evaluation: human-readable evidence lines plus
/// the subset that are hard failures.
#[derive(Debug, Default)]
pub struct GateOutcome {
    pub lines: Vec<String>,
    pub failures: Vec<String>,
}

impl GateOutcome {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }

    fn fail(&mut self, msg: String) {
        self.lines.push(format!("FAIL  {msg}"));
        self.failures.push(msg);
    }

    fn ok(&mut self, msg: String) {
        self.lines.push(format!("ok    {msg}"));
    }

    fn note(&mut self, msg: String) {
        self.lines.push(format!("note  {msg}"));
    }
}

/// Evaluate the perf gate: `current` are artifacts measured in this run,
/// `baselines` the committed trajectory (see module docs for the rules).
pub fn gate(current: &[BenchArtifact], baselines: &[BenchArtifact]) -> GateOutcome {
    let mut out = GateOutcome::default();
    let m1 = current.iter().find(|a| a.rung == "M.1");
    let c1 = current.iter().find(|a| a.rung == "C.1w8");
    match (m1, c1) {
        (Some(m1), Some(c1)) => {
            let ratio = m1.spins_per_sec / c1.spins_per_sec.max(1e-12);
            let msg = format!(
                "M.1 over C.1w8: {ratio:.2}x spins/sec (floor {MIN_M1_OVER_C1W8:.1}x; \
                 M.1 {:.1}M/s, C.1w8 {:.1}M/s)",
                m1.spins_per_sec / 1e6,
                c1.spins_per_sec / 1e6
            );
            if ratio >= MIN_M1_OVER_C1W8 {
                out.ok(msg);
            } else {
                out.fail(msg);
            }
        }
        _ => out.note(
            "ratio gate skipped: needs both an M.1 and a C.1w8 measurement in this run".into(),
        ),
    }
    let b2 = current.iter().find(|a| a.rung == "B.2");
    let b1 = current.iter().find(|a| a.rung == "B.1");
    match (b2, b1) {
        (Some(b2), Some(b1)) => {
            let ratio = b2.spins_per_sec / b1.spins_per_sec.max(1e-12);
            let msg = format!(
                "B.2 over B.1: {ratio:.2}x spins/sec (floor {MIN_B2_OVER_B1:.1}x; \
                 B.2 {:.1}M/s, B.1 {:.1}M/s)",
                b2.spins_per_sec / 1e6,
                b1.spins_per_sec / 1e6
            );
            if ratio >= MIN_B2_OVER_B1 {
                out.ok(msg);
            } else {
                out.fail(msg);
            }
        }
        _ => out.note(
            "coalescing gate skipped: needs both a B.2 and a B.1 measurement in this run".into(),
        ),
    }
    for cur in current {
        let Some(base) = baselines.iter().find(|b| b.rung == cur.rung) else {
            out.note(format!("{}: no committed baseline", cur.rung));
            continue;
        };
        if base.provenance != "measured" {
            out.note(format!(
                "{}: baseline is an {} — absolute compare skipped (refresh with \
                 `repro bench --out bench`)",
                cur.rung, base.provenance
            ));
            continue;
        }
        if base.host.fingerprint() != cur.host.fingerprint() || base.threads != cur.threads {
            out.note(format!(
                "{}: baseline host/threads differ ({} t={} vs {} t={}) — absolute compare \
                 skipped",
                cur.rung,
                base.host.fingerprint(),
                base.threads,
                cur.host.fingerprint(),
                cur.threads
            ));
            continue;
        }
        let floor = base.spins_per_sec * (1.0 - MAX_REGRESSION);
        let msg = format!(
            "{}: {:.1}M spins/s vs baseline {:.1}M/s (floor {:.1}M/s, -{:.0}%)",
            cur.rung,
            cur.spins_per_sec / 1e6,
            base.spins_per_sec / 1e6,
            floor / 1e6,
            MAX_REGRESSION * 100.0
        );
        if cur.spins_per_sec >= floor {
            out.ok(msg);
        } else {
            out.fail(msg);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SamplerSpec;

    fn small() -> RunConfig {
        RunConfig {
            width: 4,
            height: 4,
            layers: 8,
            n_models: 2,
            sweeps: 4,
            sweeps_per_round: 2,
            ..RunConfig::default()
        }
    }

    fn fake(rung: &str, rate: f64) -> BenchArtifact {
        BenchArtifact {
            schema: BENCH_SCHEMA_VERSION,
            rung: rung.into(),
            threads: 1,
            sweeps: 4,
            seconds: 0.5,
            spins_per_sec: rate,
            lane_width: 8,
            lane_fill: 1.0,
            torus_width: 12,
            torus_height: 8,
            layers: 256,
            n_models: 8,
            host: HostCaps::detect(),
            git_sha: "deadbeef".into(),
            provenance: "measured".into(),
            round_latency: None,
        }
    }

    #[test]
    fn artifacts_roundtrip_through_json() {
        let a = fake("M.1", 7.5e8);
        let back = BenchArtifact::from_json(&a.to_json()).unwrap();
        assert_eq!(back.rung, "M.1");
        assert_eq!(back.spins_per_sec.to_bits(), a.spins_per_sec.to_bits());
        assert_eq!(back.host, a.host);
        assert_eq!(back.provenance, "measured");
        assert!(back.round_latency.is_none(), "legacy artifacts stay percentile-free");
        // Future schemas are refused loudly.
        let newer = a.to_json().replace("\"schema\":1", "\"schema\":99");
        assert!(BenchArtifact::from_json(&newer).is_err());
    }

    #[test]
    fn round_latency_percentiles_roundtrip_and_refuse_partial_triples() {
        let mut a = fake("C.1w8", 2.4e8);
        a.round_latency =
            Some(LatencyPercentiles { p50_us: 1200.0, p90_us: 1500.0, p99_us: 2100.0 });
        let text = a.to_json();
        assert!(text.contains("\"round_p50_us\""));
        let back = BenchArtifact::from_json(&text).unwrap();
        let p = back.round_latency.unwrap();
        assert_eq!(p.p50_us, 1200.0);
        assert!(p.p50_us <= p.p99_us);
        // A partial triple is a malformed artifact, not a silent None.
        let partial = text.replace("\"round_p90_us\":1500,", "");
        assert!(BenchArtifact::from_json(&partial).is_err());
    }

    #[test]
    fn file_names_drop_dots_and_lowercase() {
        assert_eq!(BenchArtifact::file_name("M.1"), "BENCH_m1.json");
        assert_eq!(BenchArtifact::file_name("C.1w8"), "BENCH_c1w8.json");
        assert_eq!(BenchArtifact::file_name("A.4w16"), "BENCH_a4w16.json");
        assert_eq!(BenchArtifact::file_name("B.1"), "BENCH_b1.json");
        assert_eq!(BenchArtifact::file_name("B.2"), "BENCH_b2.json");
    }

    #[test]
    fn measure_emits_complete_artifacts_for_m1_and_c1() {
        let m1 = BenchArtifact::measure(&RunSpec::new(small(), SamplerSpec::rung(Rung::M1)))
            .unwrap();
        assert_eq!(m1.rung, "M.1");
        assert_eq!(m1.lane_width, 64);
        // 8 layers in a 64-bit word: 1/8 of the bit-lanes carry spins.
        assert!((m1.lane_fill - 0.125).abs() < 1e-12);
        assert!(m1.spins_per_sec > 0.0);
        assert_eq!(m1.provenance, "measured");

        let c1 =
            BenchArtifact::measure(&RunSpec::new(small(), SamplerSpec::rung(Rung::C1).w(8)))
                .unwrap();
        assert_eq!(c1.rung, "C.1w8");
        assert_eq!(c1.lane_width, 8);
        // 2 replicas on 8 lanes, one padded batch.
        assert!((c1.lane_fill - 0.25).abs() < 1e-12);
    }

    #[test]
    fn gate_enforces_the_m1_ratio_floor() {
        let pass = gate(&[fake("M.1", 9.0e8), fake("C.1w8", 2.4e8)], &[]);
        assert!(pass.passed(), "{:?}", pass.failures);
        let fail = gate(&[fake("M.1", 4.0e8), fake("C.1w8", 2.4e8)], &[]);
        assert!(!fail.passed());
        assert!(fail.failures[0].contains("M.1 over C.1w8"));
        // Without both measurements the ratio gate degrades to a note.
        let partial = gate(&[fake("M.1", 4.0e8)], &[]);
        assert!(partial.passed());
    }

    #[test]
    fn gate_enforces_the_b2_coalescing_floor() {
        let pass = gate(&[fake("B.1", 1.0e8), fake("B.2", 2.5e8)], &[]);
        assert!(pass.passed(), "{:?}", pass.failures);
        assert!(pass.lines.iter().any(|l| l.contains("B.2 over B.1")));
        let fail = gate(&[fake("B.1", 1.0e8), fake("B.2", 1.5e8)], &[]);
        assert!(!fail.passed());
        assert!(fail.failures.iter().any(|f| f.contains("B.2 over B.1")));
        // A lone device measurement degrades to a note, not a failure.
        let partial = gate(&[fake("B.2", 1.5e8)], &[]);
        assert!(partial.passed());
        assert!(partial.lines.iter().any(|l| l.contains("coalescing gate skipped")));
    }

    #[test]
    fn gate_compares_absolutes_only_on_matching_measured_baselines() {
        let cur = [fake("M.1", 8.0e8), fake("C.1w8", 2.0e8)];
        // Matching fingerprint, measured: a 50% regression fails.
        let regressed = gate(&cur, &[fake("M.1", 1.7e9)]);
        assert!(!regressed.passed());
        // Within tolerance passes.
        let fine = gate(&cur, &[fake("M.1", 8.2e8)]);
        assert!(fine.passed(), "{:?}", fine.failures);
        // Estimate baselines are advisory, never gated.
        let mut est = fake("M.1", 1.7e9);
        est.provenance = "estimate".into();
        let skipped = gate(&cur, &[est]);
        assert!(skipped.passed());
        assert!(skipped.lines.iter().any(|l| l.contains("estimate")));
        // Host mismatch downgrades to a note too.
        let mut other = fake("M.1", 1.7e9);
        other.host.widest_rng_width = 999;
        assert!(gate(&cur, &[other]).passed());
    }

    #[test]
    fn write_and_load_roundtrip_through_a_directory() {
        let dir = std::env::temp_dir().join("vectorising_bench_artifacts_test");
        let _ = std::fs::remove_dir_all(&dir);
        fake("M.1", 7.5e8).write_to(&dir).unwrap();
        fake("C.1w8", 2.4e8).write_to(&dir).unwrap();
        let loaded = load_dir(&dir).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].rung, "C.1w8");
        assert_eq!(loaded[1].rung, "M.1");
        assert!(load_dir(Path::new("/nonexistent/bench/dir")).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
