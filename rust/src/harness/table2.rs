//! Table 2 — speedup factors between all pairs of CPU implementations on
//! 1 core, including the compiler-optimization-disabled rows (A.1a,
//! A.2a), plus Fig 15 (the A.1b row as a series).
//!
//! The `a` rows measure the *same source* built at `opt-level = 0`
//! (cargo profile `opt0`) — the paper's VC++ "/Od" toggle.  Because a
//! process cannot re-run itself unoptimized, the harness shells out to
//! the opt0 binary (`target/opt0/repro bench-rung --json ...`) and merges
//! its JSON timings; if that binary is absent the a-rows are skipped with
//! a note telling the user to `make opt0`.

use std::path::Path;
use std::process::Command;

use crate::coordinator::{self, RunConfig, RunSpec, RungTiming};
use crate::engine::{EngineBuilder, Rung, SamplerSpec};
use crate::Result;

use super::report::{f3, Table};

/// A measured rung in the Table-2 ladder.
#[derive(Clone, Debug)]
pub struct LadderTiming {
    /// Paper row label: "A.1a", "A.1b", "A.2a", "A.2b", "A.3", "A.4".
    pub label: String,
    pub seconds: f64,
}

/// In-process (optimized-build) timings: A.1b, A.2b, A.3, A.4, plus the
/// width-8 rungs when the workload's layer count allows them.
pub fn measure_optimized(cfg: &RunConfig) -> Result<Vec<LadderTiming>> {
    let mut cfg = cfg.clone();
    cfg.threads = 1;
    let mut ladder: Vec<(SamplerSpec, &str)> = vec![
        (Rung::A1.spec(), "A.1b"),
        (Rung::A2.spec(), "A.2b"),
        (Rung::A3.spec().w(4), "A.3"),
        (Rung::A4.spec().w(4), "A.4"),
    ];
    if EngineBuilder::new(Rung::A4.spec().w(8)).layers(cfg.layers).plan().is_ok() {
        ladder.push((Rung::A3.spec().w(8), "A.3w8"));
        ladder.push((Rung::A4.spec().w(8), "A.4w8"));
    }
    if EngineBuilder::new(Rung::A4.spec().w(16)).layers(cfg.layers).plan().is_ok() {
        ladder.push((Rung::A3.spec().w(16), "A.3w16"));
        ladder.push((Rung::A4.spec().w(16), "A.4w16"));
    }
    // The multi-spin rung sweeps the ±1-coupling analogue of the same
    // geometry (same spin count and sweep schedule, different coupling
    // distribution): its column compares spins/sec, not trajectories.
    if EngineBuilder::new(Rung::M1.spec()).layers(cfg.layers).plan().is_ok() {
        ladder.push((Rung::M1.spec(), "M.1"));
    }
    let mut out = Vec::new();
    for (spec, label) in ladder {
        let t = coordinator::time_sweeps_spec(&RunSpec::new(cfg.clone(), spec))?;
        out.push(LadderTiming { label: label.to_string(), seconds: t.seconds });
    }
    Ok(out)
}

/// Shell out to the opt0 binary for the compiler-optimization-disabled
/// rows (A.1a, A.2a).  `opt0_bin` is e.g. `target/opt0/repro`.
pub fn measure_unoptimized(cfg: &RunConfig, opt0_bin: &Path) -> Result<Vec<LadderTiming>> {
    let mut out = Vec::new();
    // Legacy `--kind` spellings on purpose: the opt0 binary may be an
    // older build, and the v0 CLI surface is kept compatible.
    for (kind_arg, label) in [("a1-original", "A.1a"), ("a2-basic", "A.2a")] {
        let output = Command::new(opt0_bin)
            .args([
                "bench-rung",
                "--kind",
                kind_arg,
                "--width",
                &cfg.width.to_string(),
                "--height",
                &cfg.height.to_string(),
                "--layers",
                &cfg.layers.to_string(),
                "--models",
                &cfg.n_models.to_string(),
                "--sweeps",
                &cfg.sweeps.to_string(),
                "--json",
            ])
            .output()
            .map_err(|e| anyhow::anyhow!("running opt0 binary {opt0_bin:?}: {e}"))?;
        if !output.status.success() {
            anyhow::bail!(
                "opt0 bench-rung failed: {}",
                String::from_utf8_lossy(&output.stderr)
            );
        }
        let text = String::from_utf8_lossy(&output.stdout);
        let timing = RungTiming::from_json(text.trim())
            .map_err(|e| anyhow::anyhow!("parsing opt0 output {text:?}: {e}"))?;
        out.push(LadderTiming { label: label.to_string(), seconds: timing.seconds });
    }
    Ok(out)
}

/// The pairwise speedup matrix: entry (row i, col j) = time(i) / time(j),
/// i.e. "how many times faster is j than i" — the paper's Table 2
/// orientation (its row A.1b, column A.4 is 11.86).
pub fn pairwise(rungs: &[LadderTiming]) -> Vec<Vec<f64>> {
    rungs
        .iter()
        .map(|a| rungs.iter().map(|b| a.seconds / b.seconds).collect())
        .collect()
}

/// Paper row order: A.1a, A.1b, A.2a, A.2b, A.3, A.4, then the width-8
/// and width-16 rungs and the multi-spin rung (not in the paper — this
/// testbed's AVX2/AVX-512/bit-packing extensions).
fn paper_order(label: &str) -> usize {
    [
        "A.1a", "A.1b", "A.2a", "A.2b", "A.3", "A.4", "A.3w8", "A.4w8", "A.3w16", "A.4w16",
        "M.1",
    ]
    .iter()
    .position(|&l| l == label)
    .unwrap_or(usize::MAX)
}

/// Render Table 2 (+ Fig 15, the A.1b row) from measured timings.
pub fn render(rungs: &[LadderTiming], csv: Option<&Path>) -> Result<String> {
    let mut rungs: Vec<LadderTiming> = rungs.to_vec();
    rungs.sort_by_key(|r| paper_order(&r.label));
    let rungs = &rungs[..];
    let m = pairwise(rungs);
    let mut header: Vec<String> = vec!["".to_string()];
    header.extend(rungs.iter().map(|r| r.label.clone()));
    let mut t = Table::new(header);
    for (i, r) in rungs.iter().enumerate() {
        let mut row = vec![r.label.clone()];
        row.extend(m[i].iter().map(|&x| f3(x)));
        t.row(row);
    }
    if let Some(path) = csv {
        t.write_csv(path)?;
    }
    let mut out = t.render();

    // Fig 15: the A.1b row as a named series.
    if let Some(i_a1b) = rungs.iter().position(|r| r.label == "A.1b") {
        out.push_str("\nFig 15 (speedup over A.1b, 1 core):\n");
        for (j, r) in rungs.iter().enumerate() {
            out.push_str(&format!("  {:5} {:>8}   paper: {}\n", r.label, f3(m[i_a1b][j]), paper_fig15(&r.label)));
        }
    }
    Ok(out)
}

/// The paper's published A.1b row of Table 2 (for side-by-side display).
fn paper_fig15(label: &str) -> &'static str {
    match label {
        "A.1a" => "0.663",
        "A.1b" => "1.000",
        "A.2a" => "1.274",
        "A.2b" => "3.748",
        "A.3" => "7.053",
        "A.4" => "11.860",
        _ => "-",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairwise_matrix_properties() {
        let rungs = vec![
            LadderTiming { label: "A.1b".into(), seconds: 10.0 },
            LadderTiming { label: "A.2b".into(), seconds: 4.0 },
            LadderTiming { label: "A.4".into(), seconds: 1.0 },
        ];
        let m = pairwise(&rungs);
        for i in 0..3 {
            assert!((m[i][i] - 1.0).abs() < 1e-12, "diagonal is 1");
            for j in 0..3 {
                assert!((m[i][j] * m[j][i] - 1.0).abs() < 1e-12, "antisymmetric");
            }
        }
        assert!((m[0][2] - 10.0).abs() < 1e-12, "A.4 is 10x faster than A.1b");
    }

    #[test]
    fn extended_rows_sort_after_the_paper_ladder() {
        assert!(paper_order("A.3w16") > paper_order("A.4w8"));
        assert!(paper_order("M.1") > paper_order("A.4w16"));
        assert_eq!(paper_order("C.1w8"), usize::MAX, "unknown labels sort last");
    }

    #[test]
    fn render_contains_fig15() {
        let rungs = vec![
            LadderTiming { label: "A.1b".into(), seconds: 10.0 },
            LadderTiming { label: "A.4".into(), seconds: 1.0 },
        ];
        let s = render(&rungs, None).unwrap();
        assert!(s.contains("Fig 15"));
        assert!(s.contains("10.000"));
    }
}
