//! Fig 14 — probability of having to wait for a spin flip, per tempering
//! replica ("Ising model index"), for the scalar CPU (w=1), the
//! vectorized CPU (w=4 SSE, w=8 AVX2) and the accelerator warp (w=32).
//!
//! The measured per-replica flip probability `p_i` comes from running the
//! tempering ladder; the three curves are `1 − (1−p_i)^w` (the paper's §4
//! analysis), cross-checked against the *directly measured* quadruplet
//! wait rate of the A.4 rung.

use std::path::Path;

use crate::coordinator::{self, RunConfig};
use crate::engine::Rung;
use crate::stats::wait_probability;
use crate::Result;

use super::report::{f4, Table};

pub struct Fig14Row {
    pub index: usize,
    pub beta: f32,
    pub flip_prob: f64,
    pub wait_w1: f64,
    pub wait_w4: f64,
    pub wait_w8: f64,
    pub wait_w32: f64,
    /// Directly measured quadruplet wait rate (A.4 groups).
    pub wait_w4_measured: f64,
}

/// Run the ladder with the A.4 rung and compute the three curves.
pub fn compute(cfg: &RunConfig) -> Result<Vec<Fig14Row>> {
    let mut pt = coordinator::build_ensemble(cfg, Rung::A4.spec().w(4))?;
    let pool = coordinator::SweepPool::new(cfg.threads);
    let rounds = cfg.sweeps / cfg.sweeps_per_round;
    for _ in 0..rounds {
        coordinator::scheduler::parallel_sweep_with_pool(&mut pt, cfg.sweeps_per_round, &pool);
        pt.exchange();
    }
    let ladder = pt.ladder().clone();
    Ok(pt
        .reports()
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let p = r.stats.flip_prob();
            Fig14Row {
                index: i,
                beta: ladder.beta(i),
                flip_prob: p,
                wait_w1: wait_probability(p, 1),
                wait_w4: wait_probability(p, 4),
                wait_w8: wait_probability(p, 8),
                wait_w32: wait_probability(p, 32),
                wait_w4_measured: r.stats.wait_prob(),
            }
        })
        .collect())
}

/// Averages over the ladder — the paper's summary numbers ("the A.1 CPU
/// application must wait ... 28.6% ... GPU ... 82.8% ... A.4 ... 56.8%").
pub struct Fig14Summary {
    pub mean_flip: f64,
    pub mean_wait_w4: f64,
    pub mean_wait_w8: f64,
    pub mean_wait_w32: f64,
    /// Ratio wait(w=32)/wait(w=1) — paper: 2.9x.
    pub gpu_over_cpu: f64,
    /// Ratio wait(w=4)/wait(w=1) — paper: 2.0x.
    pub vec_over_cpu: f64,
}

pub fn summarize(rows: &[Fig14Row]) -> Fig14Summary {
    let n = rows.len() as f64;
    let mean_flip = rows.iter().map(|r| r.flip_prob).sum::<f64>() / n;
    let mean_w4 = rows.iter().map(|r| r.wait_w4).sum::<f64>() / n;
    let mean_w8 = rows.iter().map(|r| r.wait_w8).sum::<f64>() / n;
    let mean_w32 = rows.iter().map(|r| r.wait_w32).sum::<f64>() / n;
    Fig14Summary {
        mean_flip,
        mean_wait_w4: mean_w4,
        mean_wait_w8: mean_w8,
        mean_wait_w32: mean_w32,
        gpu_over_cpu: mean_w32 / mean_flip.max(1e-12),
        vec_over_cpu: mean_w4 / mean_flip.max(1e-12),
    }
}

/// Render the figure as a table (+ optional CSV).
pub fn run(cfg: &RunConfig, csv: Option<&Path>) -> Result<String> {
    let rows = compute(cfg)?;
    let mut t = Table::new(vec![
        "model",
        "beta",
        "P(flip)",
        "wait w=1 (A.1)",
        "wait w=4 (A.4)",
        "w=4 measured",
        "wait w=8 (A.4w8)",
        "wait w=32 (GPU)",
    ]);
    for r in &rows {
        t.row(vec![
            r.index.to_string(),
            format!("{:.4}", r.beta),
            f4(r.flip_prob),
            f4(r.wait_w1),
            f4(r.wait_w4),
            f4(r.wait_w4_measured),
            f4(r.wait_w8),
            f4(r.wait_w32),
        ]);
    }
    if let Some(path) = csv {
        t.write_csv(path)?;
    }
    let s = summarize(&rows);
    Ok(format!(
        "{}\nladder means: P(flip)={:.3}  wait(w=4)={:.3} ({:.2}x)  wait(w=8)={:.3}  \
         wait(w=32)={:.3} ({:.2}x)\n\
         paper means:  P(flip)=0.286  wait(w=4)=0.568 (2.0x)  wait(w=32)=0.828 (2.9x)\n",
        t.render(),
        s.mean_flip,
        s.mean_wait_w4,
        s.vec_over_cpu,
        s.mean_wait_w8,
        s.mean_wait_w32,
        s.gpu_over_cpu
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> RunConfig {
        RunConfig { n_models: 6, sweeps: 40, sweeps_per_round: 10, ..RunConfig::default() }
    }

    #[test]
    fn curves_ordered_and_monotone_in_w() {
        let rows = compute(&small()).unwrap();
        for r in &rows {
            assert!(r.wait_w1 <= r.wait_w4 + 1e-12);
            assert!(r.wait_w4 <= r.wait_w8 + 1e-12);
            assert!(r.wait_w8 <= r.wait_w32 + 1e-12);
        }
        // hot end flips more than cold end
        assert!(rows.last().unwrap().flip_prob > rows[0].flip_prob);
    }

    #[test]
    fn measured_quadruplet_wait_matches_analytic() {
        // The analytic 1-(1-p)^4 assumes independence within a quadruplet;
        // the measured rate should be close (few percent).
        let rows = compute(&small()).unwrap();
        for r in rows.iter().filter(|r| r.flip_prob > 0.05) {
            let rel = (r.wait_w4_measured - r.wait_w4).abs() / r.wait_w4.max(1e-9);
            assert!(rel < 0.15, "model {}: measured {} vs analytic {}", r.index, r.wait_w4_measured, r.wait_w4);
        }
    }
}
