//! Table 1 — the implementation matrix (configuration, not measurement).

use super::report::Table;

/// Render the paper's Table 1 for this reproduction.
pub fn render() -> String {
    let mut t = Table::new(vec![
        "Impl",
        "CPU/Accel",
        "Multi-Threaded",
        "Compiler-Opt",
        "Basic-Opts (S2)",
        "Vec MT19937+Flip (S3)",
        "Vec Data-Update (S3.1/3.2)",
    ]);
    let y = "x";
    let n = "";
    t.row(vec!["A.1a", "CPU", y, n, n, n, n]);
    t.row(vec!["A.1b", "CPU", y, y, n, n, n]);
    t.row(vec!["A.2a", "CPU", y, n, y, n, n]);
    t.row(vec!["A.2b", "CPU", y, y, y, n, n]);
    t.row(vec!["A.3", "CPU", y, y, y, y, n]);
    t.row(vec!["A.4", "CPU", y, y, y, y, y]);
    t.row(vec!["B.1", "Accel", y, y, y, n, n]);
    t.row(vec!["B.2", "Accel", y, y, y, y, y]);
    t.render()
}

#[cfg(test)]
mod tests {
    #[test]
    fn has_all_eight_rungs() {
        let s = super::render();
        for rung in ["A.1a", "A.1b", "A.2a", "A.2b", "A.3", "A.4", "B.1", "B.2"] {
            assert!(s.contains(rung), "missing {rung}");
        }
    }
}
