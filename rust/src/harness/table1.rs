//! Table 1 — the implementation matrix (configuration, not measurement),
//! extended with the vector-width axis (the `Lanes` column and the
//! width-8 CPU rungs).

use super::report::Table;

/// Render the paper's Table 1 for this reproduction.
pub fn render() -> String {
    let mut t = Table::new(vec![
        "Impl",
        "CPU/Accel",
        "Lanes",
        "Multi-Threaded",
        "Compiler-Opt",
        "Basic-Opts (S2)",
        "Vec MT19937+Flip (S3)",
        "Vec Data-Update (S3.1/3.2)",
    ]);
    let y = "x";
    let n = "";
    t.row(vec!["A.1a", "CPU", "1", y, n, n, n, n]);
    t.row(vec!["A.1b", "CPU", "1", y, y, n, n, n]);
    t.row(vec!["A.2a", "CPU", "1", y, n, y, n, n]);
    t.row(vec!["A.2b", "CPU", "1", y, y, y, n, n]);
    t.row(vec!["A.3", "CPU", "4", y, y, y, y, n]);
    t.row(vec!["A.4", "CPU", "4", y, y, y, y, y]);
    t.row(vec!["A.3w8", "CPU", "8", y, y, y, y, n]);
    t.row(vec!["A.4w8", "CPU", "8", y, y, y, y, y]);
    t.row(vec!["A.3w16", "CPU", "16", y, y, y, y, n]);
    t.row(vec!["A.4w16", "CPU", "16", y, y, y, y, y]);
    // C-rungs: lanes run across the tempering ensemble (one replica per
    // lane), not across one model's layers.
    t.row(vec!["C.1", "CPU", "4", y, y, y, y, y]);
    t.row(vec!["C.1w8", "CPU", "8", y, y, y, y, y]);
    t.row(vec!["C.1w16", "CPU", "16", y, y, y, y, y]);
    // M.1: 64 bit-lanes across one model's layers (multi-spin coding on
    // the ±1-coupling family; acceptance via per-bin thresholds).
    t.row(vec!["M.1", "CPU", "64", y, y, y, y, y]);
    t.row(vec!["B.1", "Accel", "32", y, y, y, n, n]);
    t.row(vec!["B.2", "Accel", "32", y, y, y, y, y]);
    t.render()
}

#[cfg(test)]
mod tests {
    #[test]
    fn has_all_rungs() {
        let s = super::render();
        for rung in [
            "A.1a", "A.1b", "A.2a", "A.2b", "A.3", "A.4", "A.3w8", "A.4w8", "A.3w16", "A.4w16",
            "C.1", "C.1w8", "C.1w16", "M.1", "B.1", "B.2",
        ] {
            assert!(s.contains(rung), "missing {rung}");
        }
        assert!(s.contains("Lanes"));
    }
}
