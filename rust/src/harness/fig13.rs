//! Fig 13 — relative performance of the optimization ladder across
//! thread counts, plus the accelerator variants B.1/B.2.
//!
//! The paper normalizes to "the original CPU code on 1 core" (A.1b,
//! 5705.27 s at full scale); this harness does the same on the configured
//! workload.  Thread counts beyond the machine's core count are still
//! measured (this testbed has fewer cores than the paper's i7-965) and
//! flagged in EXPERIMENTS.md.

use std::path::Path;

use crate::coordinator::{self, RunConfig, RunSpec, Timer};
use crate::engine::{EngineBuilder, Rung, SamplerSpec};
use crate::ising::builder::torus_workload;
use crate::runtime::{artifact, Runtime};
use crate::sweep::accel::{AccelSweeper, AccelVariant};
use crate::sweep::Sweeper;
use crate::Result;

use super::report::{f3, Table};

#[derive(Clone, Debug)]
pub struct Fig13Row {
    pub label: String,
    pub threads: usize,
    pub seconds: f64,
    /// Speedup over the A.1 (1-thread) baseline.
    pub relative: f64,
}

/// Time the accelerator variant over the whole ensemble (single device,
/// like the paper's one GTX-285 hosting all 115 models).
pub fn time_accel(cfg: &RunConfig, variant: AccelVariant, config_name: &str) -> Result<f64> {
    let rt = Runtime::cpu()?;
    let dir = artifact::default_dir();
    let mut sweepers: Vec<AccelSweeper> = (0..cfg.n_models)
        .map(|i| {
            let wl = torus_workload(cfg.width, cfg.height, cfg.layers, cfg.seed, cfg.jtau);
            AccelSweeper::new(&rt, &dir, config_name, variant, &wl, cfg.seed as u32 + 1000 * i as u32)
        })
        .collect::<Result<_>>()?;
    let gran = sweepers[0].granularity();
    let sweeps = (cfg.sweeps / gran).max(1) * gran;
    // warm-up call (compile caches, first-touch)
    for s in sweepers.iter_mut() {
        s.run(gran, 0.5);
    }
    let timer = Timer::start();
    for (i, s) in sweepers.iter_mut().enumerate() {
        let beta = 0.05 + 0.5 * (i as f32 + 1.0) / cfg.n_models as f32;
        s.run(sweeps, beta);
    }
    Ok(timer.seconds())
}

/// Run the full Fig-13 grid.  `thread_counts` defaults to the paper's
/// {1, 2, 4, 6, 8}; `with_accel` adds B.1/B.2 (requires artifacts).
pub fn compute(cfg: &RunConfig, thread_counts: &[usize], with_accel: bool) -> Result<Vec<Fig13Row>> {
    let mut rows = Vec::new();
    let mut baseline = None;
    let mut ladder: Vec<(SamplerSpec, &str)> = vec![
        (Rung::A1.spec(), "A.1"),
        (Rung::A2.spec(), "A.2"),
        (Rung::A3.spec().w(4), "A.3"),
        (Rung::A4.spec().w(4), "A.4"),
    ];
    // The width-8 column needs a layer count the octet interlacing supports.
    if EngineBuilder::new(Rung::A4.spec().w(8)).layers(cfg.layers).plan().is_ok() {
        ladder.push((Rung::A3.spec().w(8), "A.3w8"));
        ladder.push((Rung::A4.spec().w(8), "A.4w8"));
    }
    for (spec, label) in ladder {
        for &threads in thread_counts {
            // One Run API spec per grid cell: the workload with this
            // thread count, paired with the ladder rung's sampler.
            let mut c = cfg.clone();
            c.threads = threads;
            let t = coordinator::time_sweeps_spec(&RunSpec::new(c, spec))?;
            if spec.rung == Rung::A1 && threads == thread_counts[0] {
                baseline = Some(t.seconds);
            }
            rows.push(Fig13Row {
                label: label.to_string(),
                threads,
                seconds: t.seconds,
                relative: 0.0,
            });
        }
    }
    if with_accel {
        for (variant, label) in [(AccelVariant::B1Naive, "B.1"), (AccelVariant::B2Coalesced, "B.2")] {
            let config_name = artifact_config_for(cfg)?;
            let secs = time_accel(cfg, variant, &config_name)?;
            rows.push(Fig13Row { label: label.to_string(), threads: 1, seconds: secs, relative: 0.0 });
        }
    }
    let base = baseline.ok_or_else(|| anyhow::anyhow!("no baseline measured"))?;
    for r in rows.iter_mut() {
        r.relative = base / r.seconds;
    }
    Ok(rows)
}

/// Find the artifact config matching the run geometry.
pub fn artifact_config_for(cfg: &RunConfig) -> Result<String> {
    let dir = artifact::default_dir();
    let manifest = artifact::Manifest::load(&dir)?;
    manifest
        .artifacts
        .iter()
        .find(|a| a.static_cfg.n_base == cfg.n_base() && a.static_cfg.n_layers == cfg.layers)
        .map(|a| a.config.clone())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "no artifact matches {}x{} (run `make artifacts`, or adjust --width/--height/--layers)",
                cfg.n_base(),
                cfg.layers
            )
        })
}

/// Render Fig 13 (+ optional CSV).
pub fn render(rows: &[Fig13Row], csv: Option<&Path>) -> Result<String> {
    let mut t = Table::new(vec!["impl", "threads", "seconds", "speedup vs A.1(1t)"]);
    for r in rows {
        t.row(vec![r.label.clone(), r.threads.to_string(), format!("{:.3}", r.seconds), f3(r.relative)]);
    }
    if let Some(path) = csv {
        t.write_csv(path)?;
    }
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_ordering_holds_on_tiny_workload() {
        // A.2 must beat A.1; A.4 must beat A.2 (the paper's core claims),
        // even on a small workload.  Only meaningful in optimized builds —
        // at opt-level 0 the SIMD wrappers are function calls and the
        // ordering legitimately inverts (that is literally the paper's
        // A.xa column).
        if cfg!(debug_assertions) {
            eprintln!("skipping timing-ordering assertion in debug build");
            return;
        }
        let cfg = RunConfig {
            n_models: 2,
            sweeps: 60,
            sweeps_per_round: 10,
            ..RunConfig::default()
        };
        let rows = compute(&cfg, &[1], false).unwrap();
        let secs = |label: &str| rows.iter().find(|r| r.label == label).unwrap().seconds;
        assert!(secs("A.2") < secs("A.1"), "A.2 {} vs A.1 {}", secs("A.2"), secs("A.1"));
        assert!(secs("A.4") < secs("A.2"), "A.4 {} vs A.2 {}", secs("A.4"), secs("A.2"));
    }
}
