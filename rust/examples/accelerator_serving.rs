//! Accelerator round-trip: load the AOT artifacts (the B.1/B.2 "GPU"
//! rungs), run them against the native A.4 engine on the same workload,
//! and verify the three-layer stack composes: Pallas kernels -> JAX model
//! -> HLO text -> PJRT executable -> rust coordinator.
//!
//! Requires `make artifacts`.
//!
//! ```bash
//! cargo run --release --example accelerator_serving
//! ```

use std::time::Instant;

use vectorising::engine::{EngineBuilder, Rung};
use vectorising::ising::builder::torus_workload;
use vectorising::runtime::{artifact, Runtime};
use vectorising::sweep::accel::{AccelSweeper, AccelVariant};
use vectorising::sweep::Sweeper;

fn main() -> vectorising::Result<()> {
    let dir = artifact::default_dir();
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {} ({} device)", rt.platform_name(), rt.device_count());

    let wl = torus_workload(8, 8, 32, 1, 0.3);
    let beta = 0.8f32;
    let sweeps = 200;

    // Accelerator rungs (granularity = sweeps_per_call baked in artifact).
    let mut rows = Vec::new();
    for (variant, label) in [(AccelVariant::B1Naive, "B.1"), (AccelVariant::B2Coalesced, "B.2")] {
        let mut sw = AccelSweeper::new(&rt, &dir, "default", variant, &wl, 5489)?;
        sw.run(10, beta); // warm-up / compile caches
        let t0 = Instant::now();
        let stats = sw.run(sweeps, beta);
        let dt = t0.elapsed().as_secs_f64();
        let e_host = sw.energy();
        let e_dev = sw.artifact_energy().unwrap();
        println!(
            "{label}: {sweeps} sweeps in {dt:.3}s ({:.2}M updates/s) | P(flip)={:.4} | E_host={:.2} E_device={:.2}",
            stats.attempts as f64 / dt / 1e6,
            stats.flip_prob(),
            e_host,
            e_dev
        );
        assert!((e_host - e_dev).abs() < 0.05, "device/host energy mismatch");
        rows.push((label, dt, sw.state()));
    }

    // The two layouts must be the very same trajectory (paper §3.2: the
    // only difference between B.1 and B.2 is memory organisation).
    assert_eq!(rows[0].2, rows[1].2, "B.1 and B.2 diverged");
    println!("B.1 == B.2 trajectory: OK");
    println!("coalescing speedup (B.1/B.2 time): {:.2}x (paper: 6.78x on GTX-285)", rows[0].1 / rows[1].1);

    // Native fully-vectorized CPU rung for comparison (paper: A.4 on 8
    // cores beats the GPU by 2.04x; on 1 core it roughly ties 4 GPU-ish).
    let mut a4 = EngineBuilder::new(Rung::A4.spec().w(4))
        .build(&wl.model, &wl.s0, 5489)
        .expect("cpu sweeper");
    a4.run(10, beta);
    let t0 = Instant::now();
    let stats = a4.run(sweeps, beta);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "A.4: {sweeps} sweeps in {dt:.3}s ({:.2}M updates/s) | P(flip)={:.4} | E={:.2}",
        stats.attempts as f64 / dt / 1e6,
        stats.flip_prob(),
        a4.energy()
    );
    println!("A.4 vs B.2 speedup: {:.2}x (paper: 2.04x with 8 cores vs GTX-285)", rows[1].1 / dt);
    Ok(())
}
