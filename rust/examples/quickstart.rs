//! Quickstart: build a layered QMC Ising workload, negotiate a sampler
//! through the Engine API v1, and watch the energy relax.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use vectorising::engine::{EngineBuilder, Rung, SamplerSpec};
use vectorising::ising::builder::torus_workload;
use vectorising::sweep::Sweeper;

fn main() {
    // 8x8 torus base graph (64 spins/layer), 32 layers -> 2,048 spins.
    let wl = torus_workload(8, 8, 32, 1, 0.3);
    println!(
        "model: {} spins/layer x {} layers = {} spins, {} space edges/layer",
        wl.model.base.n,
        wl.model.n_layers,
        wl.model.n_spins(),
        wl.model.base.edges.len()
    );

    // Express intent (rung A.4, width and backend negotiated), and the
    // builder picks the instruction set this host actually has.
    let spec = SamplerSpec::rung(Rung::A4);
    let mut sim = EngineBuilder::new(spec).build(&wl.model, &wl.s0, 5489).expect("cpu sweeper");
    println!(
        "plan: {} — backend {}, {} lanes",
        sim.plan.label(),
        sim.plan.backend,
        sim.plan.width
    );
    let beta = 1.2f32;
    println!("initial energy: {:.2}", sim.energy());
    for round in 1..=10 {
        let stats = sim.run(50, beta);
        println!(
            "after {:4} sweeps: E = {:9.2}   P(flip) = {:.4}   group wait = {:.4}",
            round * 50,
            sim.energy(),
            stats.flip_prob(),
            stats.wait_prob()
        );
    }
    // the incremental effective-field bookkeeping must still be exact
    let drift = sim.validate();
    println!("h_eff consistency after 500 sweeps: {drift:.2e} (must be ~0)");
    assert!(drift < 1e-3);
}
