//! End-to-end driver: the full system on a real (scaled) workload.
//!
//! Reproduces the paper's §4 experiment structure end-to-end: a parallel-
//! tempering ladder of QMC Ising models ("115 Ising models ... 30,000
//! Metropolis sweeps"), swept by the fully vectorized A.4 engine through
//! the multi-threaded coordinator, with replica exchanges between rounds
//! — then reports throughput, per-replica flip statistics and the Fig-14
//! wait-probability curves.
//!
//! Default scale finishes in ~a minute on one core; pass `--paper-scale`
//! through the `repro run` CLI for the full 2.8M-spin configuration.
//!
//! ```bash
//! cargo run --release --example parallel_tempering
//! ```

use vectorising::coordinator::{self, RunConfig};
use vectorising::engine::Rung;
use vectorising::stats::wait_probability;

fn main() -> vectorising::Result<()> {
    // Scaled version of the paper's benchmark: 24 replicas x 2,048 spins
    // x 600 sweeps (the paper: 115 x 24,576 x 30,000).
    let cfg = RunConfig {
        width: 8,
        height: 8,
        layers: 32,
        n_models: 24,
        sweeps: 600,
        sweeps_per_round: 20,
        threads: 2,
        ..RunConfig::default()
    };
    println!(
        "ensemble: {} replicas x {} spins = {} spins, {} sweeps each ({} total updates)",
        cfg.n_models,
        cfg.n_spins_per_model(),
        cfg.total_spins(),
        cfg.sweeps,
        cfg.total_updates()
    );

    // The coordinator takes a SamplerSpec: rung A.4 pinned at the
    // paper's 4 lanes (the w=4 columns below), backend negotiated.
    let report = coordinator::run(&cfg, Rung::A4.spec().w(4))?;

    println!(
        "\nwall {:.2}s | {:.2}M spin-updates/s | swap acceptance {:.3}",
        report.wall_seconds,
        report.updates_per_sec / 1e6,
        report.swap_acceptance
    );
    println!(
        "\n{:>5} {:>9} {:>9} {:>12} {:>12} {:>13}",
        "model", "P(flip)", "w=1", "w=4 (meas.)", "w=4 (anal.)", "w=32 (anal.)"
    );
    for (i, (&p, &wm)) in report.flip_probs.iter().zip(&report.wait_probs).enumerate() {
        println!(
            "{:5} {:9.4} {:9.4} {:12.4} {:12.4} {:13.4}",
            i,
            p,
            wait_probability(p, 1),
            wm,
            wait_probability(p, 4),
            wait_probability(p, 32)
        );
    }
    let mean_p = report.flip_probs.iter().sum::<f64>() / report.flip_probs.len() as f64;
    println!(
        "\nladder means: P(flip) = {:.3}  (paper: 0.286); wait(w=32)/wait(w=1) = {:.2} (paper: 2.9x)",
        mean_p,
        report.flip_probs.iter().map(|&p| wait_probability(p, 32)).sum::<f64>()
            / report.flip_probs.len() as f64
            / mean_p
    );

    // Sanity: energies must be ladder-ordered on average (colder = lower).
    let cold = report.energies.first().unwrap();
    let hot = report.energies.last().unwrap();
    println!("cold-end energy {cold:.1}, hot-end energy {hot:.1}");
    assert!(cold < hot, "tempering ladder must order energies");
    Ok(())
}
