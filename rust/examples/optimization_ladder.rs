//! The paper's story in one binary: run the same workload through every
//! CPU rung of the optimization ladder and print the speedups
//! (a miniature of Fig 13 / Table 2).
//!
//! Each row is a `SamplerSpec` — the rung and lane width are orthogonal
//! axes, and the negotiated `Plan` names the backend that actually ran.
//!
//! ```bash
//! cargo run --release --example optimization_ladder
//! ```

use std::time::Instant;

use vectorising::engine::{EngineBuilder, Rung, SamplerSpec};
use vectorising::ising::builder::torus_workload;
use vectorising::sweep::Sweeper;

fn main() {
    let sweeps = 300;
    let beta = 0.8f32;
    println!("timing {sweeps} sweeps of a 64x32 (2,048-spin) model per rung\n");

    let mut ladder: Vec<SamplerSpec> = vec![
        Rung::A1.spec(),
        Rung::A2.spec(),
        Rung::A3.spec().w(4),
        Rung::A4.spec().w(4),
    ];
    // The width-8 (and portable width-16) rows ride along when the layer
    // count supports the interlacing — no new enum variants needed.
    for wide in [Rung::A3.spec().w(8), Rung::A4.spec().w(8), Rung::A4.spec().w(16)] {
        if EngineBuilder::new(wide).layers(32).plan().is_ok() {
            ladder.push(wide);
        }
    }

    let mut results = Vec::new();
    for spec in ladder {
        let wl = torus_workload(8, 8, 32, 1, 0.3);
        let mut sw = EngineBuilder::new(spec).build(&wl.model, &wl.s0, 5489).expect("cpu sweeper");
        sw.run(20, beta); // warm-up
        let t0 = Instant::now();
        let stats = sw.run(sweeps, beta);
        let dt = t0.elapsed().as_secs_f64();
        let per_update = dt / (sweeps as f64 * wl.model.n_spins() as f64) * 1e9;
        let label = format!("{} [{}]", sw.plan.label(), sw.plan.backend);
        results.push((label, dt, per_update, stats.flip_prob(), sw.energy()));
    }

    let baseline = results[0].1;
    println!(
        "{:18} {:>9} {:>12} {:>9} {:>10} {:>10}",
        "rung [backend]", "seconds", "ns/update", "speedup", "P(flip)", "energy"
    );
    for (label, dt, per_update, pflip, energy) in &results {
        println!(
            "{label:18} {dt:9.3} {per_update:12.2} {:8.2}x {pflip:10.4} {energy:10.1}",
            baseline / dt
        );
    }
    println!(
        "\npaper (Table 2, 1 core): A.2b = 3.16x over A.1b, A.3 = 5.95x, A.4 = 10.0x (1/0.1)"
    );
    println!("paper's exact A.1b row: A.2b 3.748x, A.3 7.053x, A.4 11.860x");
}
