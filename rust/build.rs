//! Build-time feature probe: AVX-512 intrinsics (`core::arch::x86_64`
//! `_mm512_*`) stabilized in Rust 1.89, and this crate still builds on
//! older stable toolchains.  Probe `rustc --version` and emit the
//! `has_avx512_intrinsics` cfg only when the compiler has them; the
//! `simd::avx512` module and everything that names it is gated on that
//! cfg, so older toolchains silently fall back to the portable W=16
//! lanes the engine already negotiates.

use std::env;
use std::process::Command;

fn main() {
    // Declare the custom cfg so `-D warnings` clippy/check builds accept it.
    println!("cargo:rustc-check-cfg=cfg(has_avx512_intrinsics)");
    if rustc_supports_avx512() {
        println!("cargo:rustc-cfg=has_avx512_intrinsics");
    }
    println!("cargo:rerun-if-changed=build.rs");
    println!("cargo:rerun-if-env-changed=RUSTC");
}

/// AVX-512 intrinsics are stable since 1.89.0 (2025-08-07).  Nightly and
/// beta builds of at least that version also qualify.
fn rustc_supports_avx512() -> bool {
    let rustc = env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let out = match Command::new(&rustc).arg("--version").output() {
        Ok(out) if out.status.success() => out,
        _ => return false,
    };
    let text = String::from_utf8_lossy(&out.stdout);
    parse_version(&text).map(|(major, minor)| (major, minor) >= (1, 89)).unwrap_or(false)
}

/// Parse "rustc 1.89.0 (…)" / "rustc 1.91.0-nightly (…)" into (1, 89).
fn parse_version(text: &str) -> Option<(u32, u32)> {
    let ver = text.split_whitespace().nth(1)?;
    let ver = ver.split('-').next()?;
    let mut parts = ver.split('.');
    let major = parts.next()?.parse().ok()?;
    let minor = parts.next()?.parse().ok()?;
    Some((major, minor))
}
