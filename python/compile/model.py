"""Layer-2 JAX model: the accelerator-side Metropolis sweep (paper §3.2).

Two variants of the *same* algorithm, differing only in memory layout —
exactly the paper's B.1 / B.2 split ("the code of both B.1 and B.2 are
almost identical", §3.2):

  B.2 ``sweep_coalesced``  — state is (N, L): base-vertex major, layer
      minor.  The layer axis is the interlace (lane) dimension, so
      * tau neighbours are ``roll(s, ±1, axis=1)``  — contiguous,
      * space neighbours are ``s[nbr_idx]``          — gather of whole
        contiguous lane rows,
      * flip decisions are one masked vector op per phase.
      This is the paper's layer-interlaced reordering (Fig 12b/c) mapped to
      a vector machine: corresponding spins of all layers sit adjacently.

  B.1 ``sweep_naive``      — state is flat (L*N,) in the original
      layer-major order; every neighbour access goes through a per-spin
      index table (the paper's Fig 4 "original memory layout"), i.e. an
      irregular gather per neighbour — the non-coalesced access pattern.

Both consume the identical MT19937 stream and make bit-identical flip
decisions, which the tests exploit: B.1 and B.2 must produce the *same
trajectory* (after layout conversion) from the same seed.

Scheduling: a double checkerboard.  Layers alternate parity (tau edges
always connect different parities — L must be even), and base vertices are
pre-coloured so no space edge joins two vertices of one colour.  A sweep is
``2 * C`` phases; every spin is visited exactly once per sweep, as in the
paper's Fig 1.  This is the vector-machine form of the paper's GPU schedule
(even layers then odd layers, §3.2).

RNG: one (624, L)-lane interlaced MT19937 (one generator per layer — the
paper's "random number generator for each GPU thread", interlaced as in
§3.2).  Uniform blocks are consumed through a buffer + cursor so no outputs
are discarded (paper §2.3: "we generate many random numbers at a time").
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import metropolis, mt19937


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static (baked-at-AOT-time) shape parameters of a sweep artefact."""

    n_base: int          # N: spins per layer (base-graph vertices)
    n_layers: int        # L: QMC layers (even; tau edges wrap L-1 -> 0)
    max_degree: int      # K: padded space-neighbour count per vertex
    n_colors: int        # C: base-graph colouring classes
    sweeps_per_call: int  # Metropolis sweeps executed per PJRT execute()

    def __post_init__(self):
        if self.n_layers % 2 != 0:
            raise ValueError("n_layers must be even (layer-parity checkerboard)")
        if self.n_base > mt19937.N_STATE:
            raise ValueError(
                f"n_base={self.n_base} exceeds one MT19937 block ({mt19937.N_STATE}); "
                "draw-splitting is not implemented")

    @property
    def n_spins(self) -> int:
        return self.n_base * self.n_layers

    @property
    def phases_per_sweep(self) -> int:
        return 2 * self.n_colors


def _draw_block(cfg: ModelConfig, mt, buf, cur):
    """Take the next (N, L) block of uniforms from the buffered stream.

    Refills (one vectorised twist) only when fewer than N rows remain —
    the paper's batched-generation optimisation.  Lane j of the buffer is
    the output stream of generator j, so row r gives one uniform per layer.
    """
    def refill(op):
        mt_, _buf, _cur = op
        mt2, buf2 = mt19937.twist_pallas(mt_)
        return mt2, buf2, jnp.int32(0)

    def keep(op):
        return op

    mt, buf, cur = jax.lax.cond(cur + cfg.n_base > mt19937.N_STATE,
                                refill, keep, (mt, buf, cur))
    rows = jax.lax.dynamic_slice(buf, (cur, 0), (cfg.n_base, cfg.n_layers))
    return mt, buf, cur + jnp.int32(cfg.n_base), mt19937.uniforms_from_bits(rows)


# ---------------------------------------------------------------------------
# B.2 — coalesced layout
# ---------------------------------------------------------------------------


def _phase_fields_coalesced(s, h, nbr_idx, nbr_j, jtau):
    """Energy delta for flipping every spin, (N, L) layout.

    dE(flip v,l) = 2 s_{v,l} * (h_v + sum_k J_k s_{nbr_k, l}
                                + jtau * (s_{v,l-1} + s_{v,l+1}))
    """
    gathered = s[nbr_idx]                        # (N, K, L): contiguous rows
    h_space = h[:, None] + jnp.sum(nbr_j[:, :, None] * gathered, axis=1)
    h_tau = jtau * (jnp.roll(s, 1, axis=1) + jnp.roll(s, -1, axis=1))
    return 2.0 * s * (h_space + h_tau)


def energy_coalesced(s, h, nbr_idx, nbr_j, jtau):
    """Total energy of an (N, L) state (space edges double-counted in the
    padded representation, hence the 1/2)."""
    gathered = s[nbr_idx]
    field = -jnp.sum(h[:, None] * s)
    space = -0.5 * jnp.sum(nbr_j[:, :, None] * s[:, None, :] * gathered)
    tau = -jtau * jnp.sum(s * jnp.roll(s, -1, axis=1))
    return field + space + tau


def make_sweep_coalesced(cfg: ModelConfig):
    """Build the B.2 sweep function for AOT lowering.

    Signature (all f32 unless noted):
      s        (N, L)        +-1 spins, coalesced layout
      mt       (624, L) u32  interlaced MT19937 state
      buf      (624, L) u32  buffered tempered outputs
      cur      ()  i32       cursor into buf (pass 624 to force refill)
      h        (N,)          per-vertex fields
      nbr_idx  (N, K) i32    padded space neighbours
      nbr_j    (N, K)        couplings (0 padding)
      masks    (2C, N, L)    per-phase one-hot sublattice masks, phase
                             ``parity * C + c`` (precomputed at setup time
                             — runtime inputs rather than in-graph
                             constants, both because that mirrors the
                             paper's ahead-of-time reordering and because
                             the xla_extension 0.5.1 runtime the rust
                             loader uses miscompiles the constant-folded
                             broadcast variant; see DESIGN.md §Runtime)
      beta     ()            inverse temperature of this replica
      jtau     ()            tau (inter-layer) coupling
    Returns (s', mt', buf', cur', flips, energy).
    """

    def sweep(s, mt, buf, cur, h, nbr_idx, nbr_j, masks, beta, jtau):
        def one_sweep(carry, _):
            s, mt, buf, cur, flips = carry
            for ph in range(cfg.phases_per_sweep):
                de = _phase_fields_coalesced(s, h, nbr_idx, nbr_j, jtau)
                mt, buf, cur, u = _draw_block(cfg, mt, buf, cur)
                s, nf = metropolis.flip_phase(s, de, u, masks[ph], beta)
                flips = flips + nf
            return (s, mt, buf, cur, flips), None

        (s, mt, buf, cur, flips), _ = jax.lax.scan(
            one_sweep, (s, mt, buf, cur, jnp.float32(0.0)),
            None, length=cfg.sweeps_per_call)
        energy = energy_coalesced(s, h, nbr_idx, nbr_j, jtau)
        return s, mt, buf, cur, flips, energy

    return sweep


# ---------------------------------------------------------------------------
# B.1 — naive (flat, gathered) layout
# ---------------------------------------------------------------------------


def energy_flat(s_flat, h_flat, fnbr_idx, fnbr_j):
    """Total energy of a flat state; every edge (space and tau) appears
    twice in the flat neighbour table, hence the 1/2."""
    gathered = s_flat[fnbr_idx]                   # (L*N, K+2) irregular gather
    field = -jnp.sum(h_flat * s_flat)
    pair = -0.5 * jnp.sum(fnbr_j * s_flat[:, None] * gathered)
    return field + pair


def make_sweep_naive(cfg: ModelConfig):
    """Build the B.1 sweep function for AOT lowering.

    Same algorithm and RNG stream as B.2, original layer-major flat layout:
      s_flat      (L*N,)          spin (l, v) at index l*N + v
      mt, buf, cur                as in B.2
      h_flat      (L*N,)
      fnbr_idx    (L*N, K+2) i32  ALL neighbours (space + 2 tau), flat
      fnbr_j      (L*N, K+2)      couplings incl. jtau entries
      phase_masks (2C, L*N)       flattened (parity, colour) masks
      beta        ()
    Returns (s', mt', buf', cur', flips, energy).

    The uniform for spin (l, v) is block[v, l] — the same number B.2 uses —
    reached through a transpose: the strided, non-coalesced access pattern
    the paper's B.1 exhibits.
    """
    total = cfg.n_spins

    def sweep(s, mt, buf, cur, h_flat, fnbr_idx, fnbr_j, phase_masks, beta):
        def one_sweep(carry, _):
            s, mt, buf, cur, flips = carry
            for ph in range(cfg.phases_per_sweep):
                gathered = s[fnbr_idx]                      # irregular gather
                h_eff = h_flat + jnp.sum(fnbr_j * gathered, axis=1)
                de = 2.0 * s * h_eff
                mt, buf, cur, u_block = _draw_block(cfg, mt, buf, cur)
                u = jnp.transpose(u_block).reshape(total)   # strided access
                s, nf = metropolis.flip_phase(s, de, u, phase_masks[ph], beta)
                flips = flips + nf
            return (s, mt, buf, cur, flips), None

        (s, mt, buf, cur, flips), _ = jax.lax.scan(
            one_sweep, (s, mt, buf, cur, jnp.float32(0.0)),
            None, length=cfg.sweeps_per_call)
        energy = energy_flat(s, h_flat, fnbr_idx, fnbr_j)
        return s, mt, buf, cur, flips, energy

    return sweep


# ---------------------------------------------------------------------------
# Example-argument builders (shapes only; used by aot.py and tests)
# ---------------------------------------------------------------------------


def coalesced_example_args(cfg: ModelConfig):
    f32, i32, u32 = jnp.float32, jnp.int32, jnp.uint32
    return (
        jax.ShapeDtypeStruct((cfg.n_base, cfg.n_layers), f32),            # s
        jax.ShapeDtypeStruct((mt19937.N_STATE, cfg.n_layers), u32),       # mt
        jax.ShapeDtypeStruct((mt19937.N_STATE, cfg.n_layers), u32),       # buf
        jax.ShapeDtypeStruct((), i32),                                    # cur
        jax.ShapeDtypeStruct((cfg.n_base,), f32),                         # h
        jax.ShapeDtypeStruct((cfg.n_base, cfg.max_degree), i32),          # nbr_idx
        jax.ShapeDtypeStruct((cfg.n_base, cfg.max_degree), f32),          # nbr_j
        jax.ShapeDtypeStruct((cfg.phases_per_sweep, cfg.n_base, cfg.n_layers), f32),  # masks
        jax.ShapeDtypeStruct((), f32),                                    # beta
        jax.ShapeDtypeStruct((), f32),                                    # jtau
    )


def naive_example_args(cfg: ModelConfig):
    f32, i32, u32 = jnp.float32, jnp.int32, jnp.uint32
    total, kk = cfg.n_spins, cfg.max_degree + 2
    return (
        jax.ShapeDtypeStruct((total,), f32),                              # s
        jax.ShapeDtypeStruct((mt19937.N_STATE, cfg.n_layers), u32),       # mt
        jax.ShapeDtypeStruct((mt19937.N_STATE, cfg.n_layers), u32),       # buf
        jax.ShapeDtypeStruct((), i32),                                    # cur
        jax.ShapeDtypeStruct((total,), f32),                              # h_flat
        jax.ShapeDtypeStruct((total, kk), i32),                           # fnbr_idx
        jax.ShapeDtypeStruct((total, kk), f32),                           # fnbr_j
        jax.ShapeDtypeStruct((cfg.phases_per_sweep, total), f32),         # masks
        jax.ShapeDtypeStruct((), f32),                                    # beta
    )
