"""AOT lowering: JAX sweep functions -> HLO text artefacts for the rust side.

Interchange format is **HLO text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  (See /opt/xla-example/README.md.)

Each artefact ``<name>.hlo.txt`` is accompanied by ``<name>.json``
describing the baked static config and the full input/output signature so
the rust runtime (rust/src/runtime/artifact.rs) can validate shapes before
feeding buffers.

``python -m compile.aot --out ../artifacts/manifest.json`` writes every
configured artefact plus the manifest; it is the only python entry point
in the build (`make artifacts`), and nothing here ever runs at request
time.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# Named artefact configurations.
#  - "default": the scaled workload every test/bench runs in seconds.
#  - "paper":   the paper's geometry (96 spins x 256 layers = 24,576 spins
#               per model, §4) for full-scale runs.
CONFIGS: dict[str, model.ModelConfig] = {
    "default": model.ModelConfig(n_base=64, n_layers=32, max_degree=4,
                                 n_colors=2, sweeps_per_call=10),
    "paper": model.ModelConfig(n_base=96, n_layers=256, max_degree=4,
                               n_colors=2, sweeps_per_call=10),
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args) -> list[dict]:
    return [{"shape": list(a.shape), "dtype": a.dtype.name} for a in args]


def lower_variant(cfg: model.ModelConfig, variant: str):
    """Lower one (config, variant) pair; returns (hlo_text, signature)."""
    if variant == "b2_coalesced":
        fn, args = model.make_sweep_coalesced(cfg), model.coalesced_example_args(cfg)
    elif variant == "b1_naive":
        fn, args = model.make_sweep_naive(cfg), model.naive_example_args(cfg)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered), _sig(args)


def build_all(out_dir: str, configs: list[str], variants: list[str]) -> dict:
    manifest = {"artifacts": []}
    os.makedirs(out_dir, exist_ok=True)
    for cname in configs:
        cfg = CONFIGS[cname]
        for variant in variants:
            name = f"{variant}_{cname}"
            hlo, sig = lower_variant(cfg, variant)
            hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
            with open(hlo_path, "w") as f:
                f.write(hlo)
            meta = {
                "name": name,
                "variant": variant,
                "config": cname,
                "static": dataclasses.asdict(cfg),
                "inputs": sig,
                "n_outputs": 6,
                "hlo_file": os.path.basename(hlo_path),
                "hlo_bytes": len(hlo),
            }
            with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
                json.dump(meta, f, indent=2)
            manifest["artifacts"].append(meta)
            print(f"  wrote {name}: {len(hlo)} chars")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts/manifest.json",
                   help="manifest path; artefacts land in its directory")
    p.add_argument("--configs", default="default,paper")
    p.add_argument("--variants", default="b1_naive,b2_coalesced")
    args = p.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    manifest = build_all(out_dir, args.configs.split(","), args.variants.split(","))
    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts -> {args.out}")


if __name__ == "__main__":
    main()
