"""Correctness oracles for the L1 kernels — intentionally *independent*
implementations (different style, no shared helpers) so that agreement with
the kernels is meaningful.

  * ``Mt19937Py``           : literal pure-python transcription of the
                              Matsumoto & Nishimura reference C code, used
                              for golden vectors and CPython cross-checks.
  * ``mt19937_ref_block``   : sequential (fori-loop) jnp twist — the C loop
                              executed index by index, vectorised only over
                              the lane dimension.
  * ``exp_fast_ref`` /
    ``exp_accurate_ref``    : the appendix's *analytic* formulas (mantissa /
                              exponent arithmetic in float64, no bitcasts).
  * ``sweep_phase_ref``     : brute-force Metropolis phase — recomputes the
                              full energy before/after each candidate flip.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

N_STATE = 624
M = 397
MATRIX_A = 0x9908B0DF
UPPER = 0x80000000
LOWER = 0x7FFFFFFF


class Mt19937Py:
    """Reference scalar MT19937, transcribed from the published C code."""

    def __init__(self, seed: int):
        mt = [0] * N_STATE
        mt[0] = seed & 0xFFFFFFFF
        for i in range(1, N_STATE):
            mt[i] = (1812433253 * (mt[i - 1] ^ (mt[i - 1] >> 30)) + i) & 0xFFFFFFFF
        self.mt = mt
        self.index = N_STATE

    def _generate(self) -> None:
        mt = self.mt
        for i in range(N_STATE):
            y = (mt[i] & UPPER) | (mt[(i + 1) % N_STATE] & LOWER)
            mt[i] = mt[(i + M) % N_STATE] ^ (y >> 1) ^ (MATRIX_A if y & 1 else 0)
        self.index = 0

    def next_u32(self) -> int:
        if self.index >= N_STATE:
            self._generate()
        y = self.mt[self.index]
        self.index += 1
        y ^= y >> 11
        y ^= (y << 7) & 0x9D2C5680
        y &= 0xFFFFFFFF
        y ^= (y << 15) & 0xEFC60000
        y &= 0xFFFFFFFF
        y ^= y >> 18
        return y

    def cpython_state(self):
        """State tuple accepted by ``random.Random.setstate`` — lets the
        tests validate the twist/temper against CPython's C implementation."""
        return (3, tuple(self.mt) + (self.index,), None)


def mt19937_ref_block(mt: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential jnp oracle: the C regeneration loop via fori_loop.

    ``mt`` is (624, W) uint32; returns (new_state, tempered_block).
    Deliberately index-by-index — O(624) sequential steps — so it shares no
    structure with the three-pass vectorised twist it validates.
    """
    a = jnp.uint32(MATRIX_A)

    def body(i, st):
        y = (st[i] & jnp.uint32(UPPER)) | (st[(i + 1) % N_STATE] & jnp.uint32(LOWER))
        mag = jnp.where((y & jnp.uint32(1)).astype(bool), a, jnp.uint32(0))
        return st.at[i].set(st[(i + M) % N_STATE] ^ (y >> 1) ^ mag)

    new = jax.lax.fori_loop(0, N_STATE, body, mt)
    y = new
    y = y ^ (y >> 11)
    y = y ^ ((y << 7) & jnp.uint32(0x9D2C5680))
    y = y ^ ((y << 15) & jnp.uint32(0xEFC60000))
    y = y ^ (y >> 18)
    return new, y


# ---------------------------------------------------------------------------
# Exponential oracles — appendix formulas evaluated analytically in float64.
# ---------------------------------------------------------------------------

_LOG2_E = math.log2(math.e)
_C = 2.0 * math.log(2.0) ** 2


def _interp_pow2(y: np.ndarray) -> np.ndarray:
    """f(i) for i = y*2^23 + 127*2^23: the linear interpolation of 2^y
    between integer exponents — computed from the formula
    (1 + y mod 1) * 2^floor(y), never touching bit representations."""
    fl = np.floor(y)
    return (1.0 + (y - fl)) * np.exp2(fl)


def exp_fast_ref(x: np.ndarray) -> np.ndarray:
    """Analytic model of the fast approximation, including the C-style
    truncation toward zero that the int32 conversion performs."""
    x = np.asarray(x, dtype=np.float64)
    scale = float(np.float32((1 << 23) * _LOG2_E))
    i_off = np.trunc(np.float32(np.float32(x) * np.float32(scale)).astype(np.float64))
    y = i_off / float(1 << 23)
    return (_interp_pow2(y) * _C).astype(np.float32)


def exp_accurate_ref(x: np.ndarray) -> np.ndarray:
    """Analytic model of the accurate approximation (2^{4y} interpolation,
    exact 4th root, range masking)."""
    x = np.asarray(x, dtype=np.float64)
    lo = -31.5 * math.log(2.0)
    hi = 32.0 * math.log(2.0) - 1e-3
    xc = np.clip(np.float32(x).astype(np.float64), lo, hi)
    scale = float(np.float32((1 << 25) * _LOG2_E))
    i_off = np.trunc(np.float32(np.float32(xc) * np.float32(scale)).astype(np.float64))
    y4 = i_off / float(1 << 23)
    out = (_interp_pow2(y4) * _C) ** 0.25
    out = np.where(x < lo, 0.0, out)
    out = np.where(x >= 0.0, np.maximum(out, 1.0), out)
    return out.astype(np.float32)


# ---------------------------------------------------------------------------
# Metropolis oracle — brute-force energetics.
# ---------------------------------------------------------------------------


def total_energy_ref(s, h, nbr_idx, nbr_J, jtau) -> float:
    """E = -sum_v h_v sum_l s_{v,l} - 1/2 sum J s s' - jtau sum_tau s s'.

    ``s`` is (N, L) +-1; space edges appear twice in the padded neighbour
    representation, hence the 1/2.
    """
    s = np.asarray(s, dtype=np.float64)
    h = np.asarray(h, dtype=np.float64)
    field = -(h[:, None] * s).sum()
    gathered = s[np.asarray(nbr_idx)]  # (N, K, L)
    space = -0.5 * (np.asarray(nbr_J, dtype=np.float64)[:, :, None] * s[:, None, :] * gathered).sum()
    tau = -float(jtau) * (s * np.roll(s, -1, axis=1)).sum()
    return float(field + space + tau)


def sweep_phase_ref(s, u, mask, h, nbr_idx, nbr_J, jtau, beta, exp_fn=None):
    """One checkerboard phase, each candidate flip evaluated by full-energy
    difference.  Spins inside one phase are mutually non-interacting by
    construction, so sequential evaluation equals the parallel kernel.

    ``exp_fn`` defaults to the exact exponential; pass ``exp_fast_ref`` to
    model the production artefact bit-for-bit.  Returns (new_s, n_flips).
    """
    s = np.array(s, dtype=np.float64, copy=True)
    u = np.asarray(u, dtype=np.float64)
    mask = np.asarray(mask)
    n, l = s.shape
    exp_fn = exp_fn or (lambda v: np.exp(np.asarray(v, dtype=np.float64)))
    flips = 0
    e0 = total_energy_ref(s, h, nbr_idx, nbr_J, jtau)
    for v in range(n):
        for li in range(l):
            if not mask[v, li]:
                continue
            s[v, li] = -s[v, li]
            e1 = total_energy_ref(s, h, nbr_idx, nbr_J, jtau)
            de = e1 - e0
            p = float(np.asarray(exp_fn(np.array([-beta * de])))[0])
            if u[v, li] < p:
                e0 = e1
                flips += 1
            else:
                s[v, li] = -s[v, li]
    return s, flips
