"""Bit-trick exponential approximations (paper §2.4 + Appendix).

The paper replaces the ~83-cycle ``exp`` with two approximations that
exploit the IEEE-754 binary32 layout: for a positive normal float with
integer bit pattern ``i``, ``f(i) = (1 + y mod 1) * 2^floor(y)`` where
``y = i / 2^23 - 127`` — i.e. the *bit pattern itself* is a linear
interpolation of ``2^y``.  Scaling by ``2 ln^2 2`` centres the relative
error at zero.

fast (paper: ~4 cycles):
    1. i  = round(x * 2^23 * log2(e)) + 127 * 2^23
    2. f  = bitcast<f32>(i) * 2 ln^2 2
    valid for (-126 ln 2) <= x < (128 ln 2); relative error ~ (-4%, +2%).

accurate (paper: ~11 cycles, max relative error ~1%):
    1. i  = round(x * 2^25 * log2(e)) + 127 * 2^23      (i.e. interpolate 2^{4y})
    2. f  = (bitcast<f32>(i) * 2 ln^2 2) ** (1/4)        (via rsqrt(rsqrt(.)))
    plus masking: exactly 0.0 for x < -31.5 ln 2, and >= 1.0 for x >= 0.
    valid for (-31.5 ln 2) <= x < (32 ln 2); relative error ~ (-1%, +0.5%).

The paper computes the 4th root with the approximate reciprocal-square-root
SSE instruction; XLA's ``rsqrt`` is more precise, so our accurate variant
has slightly *tighter* error than Fig 17 (the bound (-0.01, 0.005) from the
appendix holds, because it was derived assuming an exact 4th root).

Both variants are lookup-table free by design so they vectorise — that is
the paper's point.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LOG2_E = math.log2(math.e)
TWO_LN2_SQ = 2.0 * math.log(2.0) ** 2  # ~0.960906
EXP_BIAS_BITS = 127 << 23  # 0x3F800000

# Valid input ranges (paper §2.4).
FAST_LO = -126.0 * math.log(2.0)
FAST_HI = 128.0 * math.log(2.0)
ACCURATE_LO = -31.5 * math.log(2.0)
ACCURATE_HI = 32.0 * math.log(2.0)


def _bitcast_f32(i: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(i, jnp.float32)


def exp_fast(x: jnp.ndarray) -> jnp.ndarray:
    """The 4-cycle approximation. Caller must keep x within [FAST_LO, FAST_HI).

    Like the paper's fast variant, no range masking is performed ("The
    faster, less accurate approximation skips the bounds checking").
    """
    x = x.astype(jnp.float32)
    scaled = x * jnp.float32((1 << 23) * LOG2_E)
    i = scaled.astype(jnp.int32) + jnp.int32(EXP_BIAS_BITS)
    return _bitcast_f32(i) * jnp.float32(TWO_LN2_SQ)


def exp_accurate(x: jnp.ndarray) -> jnp.ndarray:
    """The 11-cycle approximation with range masking (paper Fig 7).

    Produces exactly 0.0 for x < -31.5 ln 2 and clamps the result to >= 1.0
    for x >= 0 (the Metropolis accept test needs ``min(1, e^x)`` semantics:
    any value >= 1 always accepts).
    """
    x = x.astype(jnp.float32)
    xc = jnp.clip(x, jnp.float32(ACCURATE_LO), jnp.float32(ACCURATE_HI - 1e-3))
    scaled = xc * jnp.float32((1 << 25) * LOG2_E)
    i = scaled.astype(jnp.int32) + jnp.int32(EXP_BIAS_BITS)
    interp = _bitcast_f32(i) * jnp.float32(TWO_LN2_SQ)
    # 4th root via two reciprocal-square-roots: rsqrt(rsqrt(v)) = v^{1/4}.
    root4 = jax.lax.rsqrt(jax.lax.rsqrt(interp))
    out = jnp.where(x < jnp.float32(ACCURATE_LO), jnp.float32(0.0), root4)
    return jnp.where(x >= jnp.float32(0.0), jnp.maximum(out, jnp.float32(1.0)), out)


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------


def _exp_fast_kernel(x_ref, o_ref):
    o_ref[...] = exp_fast(x_ref[...])


def _exp_accurate_kernel(x_ref, o_ref):
    o_ref[...] = exp_accurate(x_ref[...])


@functools.partial(jax.jit, static_argnames=())
def exp_fast_pallas(x: jnp.ndarray) -> jnp.ndarray:
    """Pallas-kernel version of :func:`exp_fast` (interpret mode)."""
    return pl.pallas_call(
        _exp_fast_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=())
def exp_accurate_pallas(x: jnp.ndarray) -> jnp.ndarray:
    """Pallas-kernel version of :func:`exp_accurate` (interpret mode)."""
    return pl.pallas_call(
        _exp_accurate_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))
