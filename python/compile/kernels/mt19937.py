"""W-way interlaced MT19937 — the paper's §3 vectorized Mersenne Twister.

The paper interlaces 4 independent MT19937 generators so that one SSE
operation advances all 4 in lock-step ("keeps 4x624=2,496 numbers and uses
SSE to generate 4 random numbers in roughly the same time as each random
number before").  On a vector machine the natural generalisation is a
``(624, W)`` uint32 state whose trailing (lane) dimension indexes the W
interlaced generators; every scalar op of the reference algorithm becomes
one W-wide vector op.

The classic generation loop

    for i in 0..624:
        y     = (mt[i] & UPPER) | (mt[(i+1) % 624] & LOWER)
        mt[i] = mt[(i+397) % 624] ^ (y >> 1) ^ (MATRIX_A if y & 1 else 0)

is *sequential*: for i >= 227 the source ``mt[(i+397) % 624]`` has already
been rewritten earlier in the same loop.  It decomposes exactly into three
fully-vectorisable passes (227 + 227 + 170 = 624):

  pass 1, i in [0, 227)   : sources mt[397..624)      -- all old values
  pass 2, i in [227, 454) : sources mt[0..227)        -- all pass-1 output
  pass 3, i in [454, 624) : sources mt[227..397)      -- all pass-2 output;
                            the y-term for i = 623 reads mt[0], which is
                            pass-1 output (the single wrap-around).

This file provides both the plain-jnp implementation (used by L2 and by the
tests as a mid-level reference) and the Pallas kernel (the L1 artefact).
Both are bit-exact against ``ref.mt19937_ref_block`` and against CPython's
``random`` module (see python/tests/test_mt19937.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

N_STATE = 624
M_SHIFT = 397
MATRIX_A = 0x9908B0DF
UPPER_MASK = 0x80000000
LOWER_MASK = 0x7FFFFFFF

# Tempering constants (Matsumoto & Nishimura 1998, Table II).
TEMPER_B = 0x9D2C5680
TEMPER_C = 0xEFC60000


def init_state(seeds) -> np.ndarray:
    """init_genrand for each lane; returns (624, W) uint32.

    ``seeds`` is a sequence of W per-lane seeds (the paper uses "4 MT19937
    random number generators with different seeds").  Pure numpy: seeding
    happens once at build/setup time, never on the request path.
    """
    seeds = np.asarray(seeds, dtype=np.uint64)
    w = seeds.shape[0]
    mt = np.empty((N_STATE, w), dtype=np.uint64)
    mt[0] = seeds & 0xFFFFFFFF
    for i in range(1, N_STATE):
        prev = mt[i - 1]
        mt[i] = (1812433253 * (prev ^ (prev >> 30)) + i) & 0xFFFFFFFF
    return mt.astype(np.uint32)


def temper(y: jnp.ndarray) -> jnp.ndarray:
    """MT19937 output tempering, elementwise on uint32."""
    y = y ^ (y >> 11)
    y = y ^ ((y << 7) & jnp.uint32(TEMPER_B))
    y = y ^ ((y << 15) & jnp.uint32(TEMPER_C))
    y = y ^ (y >> 18)
    return y


def _twist_math(mt: jnp.ndarray) -> jnp.ndarray:
    """The three-pass vectorized twist on a (624, W) uint32 state."""
    upper = jnp.uint32(UPPER_MASK)
    lower = jnp.uint32(LOWER_MASK)

    def mix(cur, nxt, src):
        y = (cur & upper) | (nxt & lower)
        mag = jnp.where((y & jnp.uint32(1)).astype(bool),
                        jnp.uint32(MATRIX_A), jnp.uint32(0))
        return src ^ (y >> 1) ^ mag

    # pass 1: i in [0, 227)
    new1 = mix(mt[0:227], mt[1:228], mt[M_SHIFT:N_STATE])
    # pass 2: i in [227, 454); sources are pass-1 rows [0, 227)
    new2 = mix(mt[227:454], mt[228:455], new1)
    # pass 3: i in [454, 624); y for i = 623 wraps to new mt[0] (pass 1),
    # sources are pass-2 rows [0, 170)
    nxt3 = jnp.concatenate([mt[455:N_STATE], new1[0:1]], axis=0)
    new3 = mix(mt[454:N_STATE], nxt3, new2[0:170])

    return jnp.concatenate([new1, new2, new3], axis=0)


def twist(mt: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Advance the interlaced state one full period.

    Returns ``(new_state, tempered_block)``: the regenerated (624, W) state
    and the (624, W) block of tempered outputs — 624*W random uint32 per
    call, lane j being the next 624 outputs of generator j.
    """
    new_state = _twist_math(mt)
    return new_state, temper(new_state)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------


def _twist_kernel(mt_ref, state_out_ref, rand_out_ref):
    """Pallas kernel body: one twist + temper of the whole state block.

    The full (624, W) state fits comfortably in VMEM for every configuration
    used here (624*128 lanes * 4 B = 312 KiB), so the BlockSpec is the whole
    array: one HBM->VMEM round-trip per twist, all compute lane-contiguous
    on the VPU.  This mirrors the paper's design point — the interlaced
    generators make the *memory traffic itself* vector shaped.
    """
    mt = mt_ref[...]
    new_state = _twist_math(mt)
    state_out_ref[...] = new_state
    rand_out_ref[...] = temper(new_state)


@functools.partial(jax.jit, static_argnames=())
def twist_pallas(mt: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Pallas-kernel version of :func:`twist` (interpret mode on CPU)."""
    w = mt.shape[1]
    out_shapes = (
        jax.ShapeDtypeStruct((N_STATE, w), jnp.uint32),
        jax.ShapeDtypeStruct((N_STATE, w), jnp.uint32),
    )
    return pl.pallas_call(
        _twist_kernel,
        out_shape=out_shapes,
        interpret=True,
    )(mt)


def uniforms_from_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Map uint32 -> f32 uniform in [0, 1) with 24-bit resolution.

    Uses the top 24 bits (``(u >> 8) * 2^-24``), the standard mapping that
    is exactly representable in f32 — matching what the paper's assembly
    does before the flip-probability compare.
    """
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
