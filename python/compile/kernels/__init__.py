"""Layer-1 Pallas kernels for the explicit-vectorization reproduction.

Modules
-------
mt19937    : W-way interlaced Mersenne Twister block generator (paper §3).
exp_approx : bit-trick exponential approximations (paper §2.4 + Appendix).
metropolis : masked vector flip kernel (paper §3.1 "vectorized flipping").
ref        : pure-jnp / pure-python correctness oracles for all of the above.

All kernels are lowered with ``interpret=True`` so the resulting HLO runs on
any PJRT backend, including the rust CPU client on the request path.
"""
