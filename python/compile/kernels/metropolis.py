"""Masked vector flip kernel — the paper's §3.1 "vectorized flipping".

Given per-spin energy deltas, uniforms and a sublattice mask, decide and
apply every flip of the phase in one wide operation:

    p    = exp_fast(-beta * dE)        (paper §2.4 fast approximation —
                                        ">= 1 always accepts" gives the
                                        min(1, .) Metropolis semantics)
    flip = (u < p) & mask              (the paper's Figure-10 mask trick)
    s'   = flip ? -s : s

The kernel is elementwise over arbitrary shape, so the same artefact body
serves the coalesced (N, L) layout (B.2) and the flat gathered layout
(B.1): the layouts differ only in how the *inputs* were produced, which is
exactly the paper's point — B.1 and B.2 run "almost identical" code and
differ only in memory organisation.

Clamping: the fast approximation is only valid for x >= -126 ln 2; larger
negative arguments wrap the exponent bits.  dE is clamped so that
-beta*dE >= -80 — probabilities below e^-80 are (far) below the 2^-24
resolution of the uniforms, so the clamp never changes a decision.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import exp_approx

_CLAMP = -80.0


def _flip_kernel(s_ref, de_ref, u_ref, mask_ref, beta_ref, s_out_ref, flips_out_ref):
    s = s_ref[...]
    de = de_ref[...]
    u = u_ref[...]
    mask = mask_ref[...]
    beta = beta_ref[0]
    x = jnp.maximum(-beta * de, jnp.float32(_CLAMP))
    p = exp_approx.exp_fast(x)
    flip = jnp.logical_and(u < p, mask > jnp.float32(0.5))
    s_out_ref[...] = jnp.where(flip, -s, s)
    flips_out_ref[...] = jnp.sum(flip.astype(jnp.float32), keepdims=True).reshape(flips_out_ref.shape)


def flip_phase(s: jnp.ndarray, de: jnp.ndarray, u: jnp.ndarray,
               mask: jnp.ndarray, beta: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Apply one masked flip phase via the Pallas kernel.

    Arguments are all f32 with identical shape except ``beta`` (scalar).
    Returns ``(s_new, n_flips)`` with ``n_flips`` a f32 scalar.
    """
    beta_arr = jnp.reshape(beta.astype(jnp.float32), (1,))
    out_shapes = (
        jax.ShapeDtypeStruct(s.shape, jnp.float32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )
    s_new, flips = pl.pallas_call(
        _flip_kernel,
        out_shape=out_shapes,
        interpret=True,
    )(s, de, u, mask, beta_arr)
    return s_new, flips[0]


def flip_phase_ref(s, de, u, mask, beta):
    """Plain-jnp twin of :func:`flip_phase` (used by tests and by HLO-size
    comparisons; must match the kernel bit-for-bit)."""
    x = jnp.maximum(-beta * de, jnp.float32(_CLAMP))
    p = exp_approx.exp_fast(x)
    flip = jnp.logical_and(u < p, mask > jnp.float32(0.5))
    return jnp.where(flip, -s, s), jnp.sum(flip.astype(jnp.float32))
