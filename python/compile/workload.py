"""Synthetic QMC workload builder — python twin of ``rust/src/ising/builder.rs``.

The graph topology, fields and couplings are *runtime inputs* to the AOT
artefacts (only shapes are baked), so this module exists for the python
tests and for generating example inputs; the rust builder is the
authoritative production path.  Both sides build the same structure: a
toroidal-grid base graph (bipartite, degree 4 — within the paper's "each
spin is adjacent to 6, 7, or 8 other spins" once the 2 tau edges are
added), L identical layers, couplings from a deterministic LCG so the two
languages can cross-check bit-identical inputs.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import model
from .kernels import mt19937


class Lcg:
    """Deterministic 64-bit LCG (MMIX constants) shared with the rust
    builder; used only to synthesise h/J values, never for Monte Carlo."""

    MUL = 6364136223846793005
    INC = 1442695040888963407

    def __init__(self, seed: int):
        self.state = (seed * 2 + 1) & 0xFFFFFFFFFFFFFFFF

    def next_u64(self) -> int:
        self.state = (self.state * self.MUL + self.INC) & 0xFFFFFFFFFFFFFFFF
        return self.state

    def next_unit(self) -> float:
        """Uniform in [-1, 1) with 21-bit resolution (exact in f32)."""
        return ((self.next_u64() >> 43) / float(1 << 20)) - 1.0


@dataclasses.dataclass
class Workload:
    cfg: model.ModelConfig
    h: np.ndarray            # (N,) f32
    nbr_idx: np.ndarray      # (N, K) i32, padded with self-loops J=0
    nbr_j: np.ndarray        # (N, K) f32
    colors: np.ndarray       # (C, N) f32 one-hot colouring masks
    jtau: float
    s0: np.ndarray           # (N, L) f32 initial +-1 state


def build_torus_workload(width: int, height: int, n_layers: int,
                         sweeps_per_call: int = 1, seed: int = 1,
                         jtau: float = 0.3) -> Workload:
    """Toroidal ``width x height`` grid (both even => bipartite), L layers."""
    if width % 2 or height % 2:
        raise ValueError("torus dims must be even for a 2-colouring")
    n = width * height
    cfg = model.ModelConfig(n_base=n, n_layers=n_layers, max_degree=4,
                            n_colors=2, sweeps_per_call=sweeps_per_call)
    rng = Lcg(seed)

    def vid(x, y):
        return (y % height) * width + (x % width)

    nbr_idx = np.zeros((n, 4), dtype=np.int32)
    nbr_j = np.zeros((n, 4), dtype=np.float32)
    # Couplings are per *undirected* edge; generate on the canonical
    # (+x, +y) edge of each vertex and mirror to the neighbour's slot.
    jx = np.zeros((height, width), dtype=np.float32)
    jy = np.zeros((height, width), dtype=np.float32)
    for y in range(height):
        for x in range(width):
            jx[y, x] = rng.next_unit()
            jy[y, x] = rng.next_unit()
    for y in range(height):
        for x in range(width):
            v = vid(x, y)
            nbr_idx[v] = [vid(x + 1, y), vid(x - 1, y), vid(x, y + 1), vid(x, y - 1)]
            nbr_j[v] = [jx[y, x], jx[y, (x - 1) % width], jy[y, x], jy[(y - 1) % height, x]]

    h = np.array([rng.next_unit() * 0.5 for _ in range(n)], dtype=np.float32)
    colors = np.zeros((2, n), dtype=np.float32)
    for y in range(height):
        for x in range(width):
            colors[(x + y) % 2, vid(x, y)] = 1.0

    s0 = np.empty((n, n_layers), dtype=np.float32)
    for v in range(n):
        for l in range(n_layers):
            s0[v, l] = 1.0 if (rng.next_u64() >> 63) else -1.0
    return Workload(cfg=cfg, h=h, nbr_idx=nbr_idx, nbr_j=nbr_j,
                    colors=colors, jtau=jtau, s0=s0)


def to_flat(w: Workload):
    """Convert a workload to the B.1 flat representation.

    Flat index of spin (l, v) is ``l*N + v`` (original layer-major order).
    Returns (s_flat, h_flat, fnbr_idx, fnbr_j, phase_masks).
    """
    cfg, n, ll = w.cfg, w.cfg.n_base, w.cfg.n_layers
    total, kk = cfg.n_spins, cfg.max_degree + 2

    s_flat = np.empty(total, dtype=np.float32)
    h_flat = np.empty(total, dtype=np.float32)
    fnbr_idx = np.zeros((total, kk), dtype=np.int32)
    fnbr_j = np.zeros((total, kk), dtype=np.float32)
    for l in range(ll):
        for v in range(n):
            f = l * n + v
            s_flat[f] = w.s0[v, l]
            h_flat[f] = w.h[v]
            for k in range(cfg.max_degree):
                fnbr_idx[f, k] = l * n + w.nbr_idx[v, k]
                fnbr_j[f, k] = w.nbr_j[v, k]
            # tau edges placed last — paper §2.2's edge reordering
            fnbr_idx[f, kk - 2] = ((l - 1) % ll) * n + v
            fnbr_idx[f, kk - 1] = ((l + 1) % ll) * n + v
            fnbr_j[f, kk - 2] = w.jtau
            fnbr_j[f, kk - 1] = w.jtau

    masks = np.zeros((cfg.phases_per_sweep, total), dtype=np.float32)
    for l in range(ll):
        for v in range(n):
            for c in range(cfg.n_colors):
                if w.colors[c, v] > 0.5:
                    masks[(l % 2) * cfg.n_colors + c, l * n + v] = 1.0
    return s_flat, h_flat, fnbr_idx, fnbr_j, masks


def coalesced_masks(w: Workload) -> np.ndarray:
    """Per-phase sublattice masks for the B.2 layout: (2C, N, L), phase
    ``parity * C + c`` — one-hot over spins whose layer parity and vertex
    colour match the phase."""
    cfg = w.cfg
    n, ll, c_n = cfg.n_base, cfg.n_layers, cfg.n_colors
    masks = np.zeros((cfg.phases_per_sweep, n, ll), dtype=np.float32)
    for l in range(ll):
        for v in range(n):
            for c in range(c_n):
                if w.colors[c, v] > 0.5:
                    masks[(l % 2) * c_n + c, v, l] = 1.0
    return masks


def fresh_rng(cfg: model.ModelConfig, seed: int = 5489):
    """(mt, buf, cur) triple forcing a refill on first draw — lane j is
    generator ``seed + j``, the paper's 'different seeds' interlacing."""
    mt = mt19937.init_state([seed + j for j in range(cfg.n_layers)])
    buf = np.zeros_like(mt)
    return mt, buf, np.int32(mt19937.N_STATE)
