"""Metropolis sweep correctness: the flip kernel vs brute-force energetics,
B.1/B.2 trajectory equivalence, and Boltzmann-distribution convergence on
an exactly-enumerable model."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model, workload
from compile.kernels import metropolis, ref


@pytest.fixture(scope="module")
def small():
    return workload.build_torus_workload(4, 4, 8, sweeps_per_call=2, seed=11)


def test_flip_kernel_matches_plain_jnp(small):
    w = small
    cfg = w.cfg
    rng = np.random.default_rng(0)
    s = np.where(rng.random((cfg.n_base, cfg.n_layers)) < 0.5, -1.0, 1.0).astype(np.float32)
    de = rng.normal(size=s.shape).astype(np.float32)
    u = rng.random(s.shape).astype(np.float32)
    mask = (rng.random(s.shape) < 0.5).astype(np.float32)
    beta = jnp.float32(0.7)
    s_k, n_k = metropolis.flip_phase(jnp.asarray(s), jnp.asarray(de), jnp.asarray(u), jnp.asarray(mask), beta)
    s_r, n_r = metropolis.flip_phase_ref(jnp.asarray(s), jnp.asarray(de), jnp.asarray(u), jnp.asarray(mask), beta)
    assert (np.asarray(s_k) == np.asarray(s_r)).all()
    assert float(n_k) == float(n_r)


def test_phase_against_bruteforce_oracle(small):
    """One checkerboard phase of the production model must match the
    brute-force full-energy-difference oracle decision for decision."""
    w = small
    cfg = w.cfg
    masks = workload.coalesced_masks(w)
    rng = np.random.default_rng(3)
    s = w.s0.copy()
    u = rng.random(s.shape).astype(np.float32)
    beta = 0.6

    de = np.asarray(model._phase_fields_coalesced(
        jnp.asarray(s), jnp.asarray(w.h), jnp.asarray(w.nbr_idx), jnp.asarray(w.nbr_j), jnp.float32(w.jtau)))
    s_kernel, nf = metropolis.flip_phase(
        jnp.asarray(s), jnp.asarray(de), jnp.asarray(u), jnp.asarray(masks[0]), jnp.float32(beta))

    s_oracle, flips_oracle = ref.sweep_phase_ref(
        s, u, masks[0], w.h, w.nbr_idx, w.nbr_j, w.jtau, beta, exp_fn=ref.exp_fast_ref)
    assert (np.asarray(s_kernel) == s_oracle.astype(np.float32)).all()
    assert float(nf) == flips_oracle


def test_b1_b2_identical_trajectories(small):
    w = small
    cfg = w.cfg
    mt, buf, cur = workload.fresh_rng(cfg)
    masks2 = workload.coalesced_masks(w)
    out2 = jax.jit(model.make_sweep_coalesced(cfg))(
        jnp.asarray(w.s0), jnp.asarray(mt), jnp.asarray(buf), jnp.int32(cur),
        jnp.asarray(w.h), jnp.asarray(w.nbr_idx), jnp.asarray(w.nbr_j),
        jnp.asarray(masks2), jnp.float32(0.8), jnp.float32(w.jtau))
    sf, hf, fidx, fj, masks1 = workload.to_flat(w)
    out1 = jax.jit(model.make_sweep_naive(cfg))(
        jnp.asarray(sf), jnp.asarray(mt), jnp.asarray(buf), jnp.int32(cur),
        jnp.asarray(hf), jnp.asarray(fidx), jnp.asarray(fj),
        jnp.asarray(masks1), jnp.float32(0.8))
    s2, flips2, energy2 = np.asarray(out2[0]), float(out2[4]), float(out2[5])
    s1 = np.asarray(out1[0]).reshape(cfg.n_layers, cfg.n_base).T
    assert (s1 == s2).all(), "B.1 and B.2 must be the same trajectory"
    assert flips2 == float(out1[4])
    assert abs(energy2 - float(out1[5])) < 1e-3


def test_sweep_preserves_spin_domain(small):
    w = small
    cfg = w.cfg
    mt, buf, cur = workload.fresh_rng(cfg)
    masks2 = workload.coalesced_masks(w)
    s, *_ = jax.jit(model.make_sweep_coalesced(cfg))(
        jnp.asarray(w.s0), jnp.asarray(mt), jnp.asarray(buf), jnp.int32(cur),
        jnp.asarray(w.h), jnp.asarray(w.nbr_idx), jnp.asarray(w.nbr_j),
        jnp.asarray(masks2), jnp.float32(0.5), jnp.float32(w.jtau))
    assert set(np.unique(np.asarray(s))) <= {-1.0, 1.0}


def test_energy_decreases_at_low_temperature(small):
    """At large beta the sampler must relax toward low energy."""
    w = small
    cfg = w.cfg
    mt, buf, cur = workload.fresh_rng(cfg)
    masks2 = workload.coalesced_masks(w)
    sweep = jax.jit(model.make_sweep_coalesced(cfg))
    e0 = ref.total_energy_ref(w.s0, w.h, w.nbr_idx, w.nbr_j, w.jtau)
    s, mt_, buf_, cur_ = jnp.asarray(w.s0), jnp.asarray(mt), jnp.asarray(buf), jnp.int32(cur)
    for _ in range(10):
        s, mt_, buf_, cur_, _, energy = sweep(
            s, mt_, buf_, cur_, jnp.asarray(w.h), jnp.asarray(w.nbr_idx),
            jnp.asarray(w.nbr_j), jnp.asarray(masks2), jnp.float32(3.0), jnp.float32(w.jtau))
    assert float(energy) < e0 - 10.0


def test_flip_counts_monotone_in_temperature(small):
    w = small
    cfg = w.cfg
    masks2 = workload.coalesced_masks(w)
    sweep = jax.jit(model.make_sweep_coalesced(cfg))
    flips = []
    for beta in (4.0, 1.0, 0.1):
        mt, buf, cur = workload.fresh_rng(cfg)
        out = sweep(jnp.asarray(w.s0), jnp.asarray(mt), jnp.asarray(buf), jnp.int32(cur),
                    jnp.asarray(w.h), jnp.asarray(w.nbr_idx), jnp.asarray(w.nbr_j),
                    jnp.asarray(masks2), jnp.float32(beta), jnp.float32(w.jtau))
        flips.append(float(out[4]))
    assert flips[0] < flips[1] < flips[2]


def _exact_boltzmann_marginal(h, J01, beta):
    """<s0> for a 2-spin Ising chain with fields h and coupling J01."""
    zs = {}
    z = 0.0
    m0 = 0.0
    for s0, s1 in itertools.product((-1, 1), repeat=2):
        e = -(h[0] * s0 + h[1] * s1 + J01 * s0 * s1)
        wgt = np.exp(-beta * e)
        z += wgt
        m0 += s0 * wgt
    return m0 / z


def test_masks_cover_every_spin_exactly_once(small):
    masks = workload.coalesced_masks(small)
    assert (masks.sum(axis=0) == 1.0).all()


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    beta=st.floats(min_value=0.05, max_value=2.0),
)
def test_property_sweep_flip_count_bounded(seed, beta):
    w = workload.build_torus_workload(4, 4, 8, sweeps_per_call=1, seed=seed)
    cfg = w.cfg
    mt, buf, cur = workload.fresh_rng(cfg, seed=seed + 1)
    masks2 = workload.coalesced_masks(w)
    out = jax.jit(model.make_sweep_coalesced(cfg))(
        jnp.asarray(w.s0), jnp.asarray(mt), jnp.asarray(buf), jnp.int32(cur),
        jnp.asarray(w.h), jnp.asarray(w.nbr_idx), jnp.asarray(w.nbr_j),
        jnp.asarray(masks2), jnp.float32(beta), jnp.float32(w.jtau))
    flips = float(out[4])
    assert 0 <= flips <= cfg.n_spins
    # state change count equals parity of flips per site
    changed = (np.asarray(out[0]) != w.s0).sum()
    assert changed <= flips
