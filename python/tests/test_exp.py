"""Exponential-approximation kernels vs the analytic oracles and the paper's
published error bounds (Fig 17 / Appendix)."""

import math

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import exp_approx as ea
from compile.kernels import ref

LN2 = math.log(2.0)


def _rel_err(approx, x):
    return approx.astype(np.float64) / np.exp(x.astype(np.float64)) - 1.0


def test_fast_error_bounds_paper_fig17():
    x = np.linspace(-80, 80, 400_001).astype(np.float32)
    r = _rel_err(np.asarray(ea.exp_fast(jnp.asarray(x))), x)
    assert r.min() > -0.0400
    assert r.max() < 0.0205
    # error oscillates around zero by design (the 2 ln^2 2 factor)
    assert abs(r.mean()) < 2e-3


def test_accurate_error_bounds_paper_appendix():
    x = np.linspace(-21.0, -1e-3, 400_001).astype(np.float32)
    r = _rel_err(np.asarray(ea.exp_accurate(jnp.asarray(x))), x)
    assert r.min() > -0.0101
    assert r.max() < 0.0051


def test_accurate_masking_below_domain():
    x = np.float32([-21.9, -22.0, -30.0, -1000.0])
    out = np.asarray(ea.exp_accurate(jnp.asarray(x)))
    assert (out == 0.0).all()


def test_accurate_clamps_to_one_for_non_negative():
    x = np.linspace(0.0, 20.0, 10_001).astype(np.float32)
    out = np.asarray(ea.exp_accurate(jnp.asarray(x)))
    assert (out >= 1.0).all()


def test_fast_matches_analytic_reference():
    x = np.linspace(-50, 50, 200_001).astype(np.float32)
    approx = np.asarray(ea.exp_fast(jnp.asarray(x)))
    oracle = ref.exp_fast_ref(x)
    rel = np.abs(approx - oracle) / np.maximum(np.abs(oracle), 1e-30)
    # The oracle models the truncation analytically; agreement is to a few
    # ULP (the trunc boundary can differ by one integer step).
    assert np.quantile(rel, 0.999) < 1e-5
    assert rel.max() < 1e-3


def test_accurate_matches_analytic_reference():
    x = np.linspace(-21, 20, 200_001).astype(np.float32)
    approx = np.asarray(ea.exp_accurate(jnp.asarray(x)))
    oracle = ref.exp_accurate_ref(x)
    mask = x < 0  # clamp region is compared in its own test
    rel = np.abs(approx[mask] - oracle[mask]) / np.maximum(np.abs(oracle[mask]), 1e-30)
    assert np.quantile(rel, 0.999) < 1e-5


def test_pallas_kernels_bitexact_vs_jnp():
    x = np.linspace(-20, 20, 100_001).astype(np.float32)
    assert (np.asarray(ea.exp_fast_pallas(jnp.asarray(x))) == np.asarray(ea.exp_fast(jnp.asarray(x)))).all()
    assert (
        np.asarray(ea.exp_accurate_pallas(jnp.asarray(x)))
        == np.asarray(ea.exp_accurate(jnp.asarray(x)))
    ).all()


def test_exactness_at_power_of_two_knots():
    """At x = k ln 2 the interpolation is exact, so the only error is the
    2 ln^2 2 scaling (paper Appendix)."""
    for k in range(-20, 20):
        x = np.float32(k * LN2)
        rel = float(np.asarray(ea.exp_fast(jnp.asarray(x)))) / math.exp(float(x)) - 1.0
        assert abs(rel - (2 * LN2 * LN2 - 1.0)) < 2e-3, (k, rel)


@settings(max_examples=300, deadline=None)
@given(x=st.floats(min_value=-80.0, max_value=80.0, allow_nan=False))
def test_property_fast_bounds_hold_pointwise(x):
    x32 = np.float32(x)
    approx = float(np.asarray(ea.exp_fast(jnp.asarray(x32))))
    rel = approx / math.exp(float(x32)) - 1.0
    assert -0.0400 < rel < 0.0205


@settings(max_examples=300, deadline=None)
@given(x=st.floats(min_value=-21.5, max_value=21.5, allow_nan=False))
def test_property_accurate_monotone_adjacent(x):
    """Accuracy property the Metropolis test relies on: approximate
    probabilities respect ordering of inputs at the resolution we use."""
    x32 = np.float32(x)
    a = float(np.asarray(ea.exp_accurate(jnp.asarray(x32))))
    b = float(np.asarray(ea.exp_accurate(jnp.asarray(x32 + np.float32(0.1)))))
    assert b >= a * 0.999  # monotone up to float noise


def test_shapes_and_dtypes_preserved():
    for shape in [(), (7,), (3, 5), (2, 3, 4)]:
        x = jnp.zeros(shape, jnp.float32)
        assert ea.exp_fast(x).shape == shape
        assert ea.exp_accurate(x).shape == shape
        assert ea.exp_fast(x).dtype == jnp.float32
