"""L2 model-level tests: config validation, scan behaviour, RNG buffer
cursor bookkeeping, energy formulas, and AOT lowering health."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, workload
from compile.kernels import mt19937, ref


def test_config_validation():
    with pytest.raises(ValueError):
        model.ModelConfig(n_base=4, n_layers=7, max_degree=4, n_colors=2, sweeps_per_call=1)
    with pytest.raises(ValueError):
        model.ModelConfig(n_base=1000, n_layers=8, max_degree=4, n_colors=2, sweeps_per_call=1)
    cfg = model.ModelConfig(n_base=64, n_layers=32, max_degree=4, n_colors=2, sweeps_per_call=10)
    assert cfg.n_spins == 2048
    assert cfg.phases_per_sweep == 4


def test_draw_block_cursor_and_refill():
    cfg = model.ModelConfig(n_base=64, n_layers=8, max_degree=4, n_colors=2, sweeps_per_call=1)
    mt0, buf0, cur0 = workload.fresh_rng(cfg)

    @jax.jit
    def draws(mt, buf, cur):
        outs = []
        for _ in range(12):  # 12*64 = 768 rows -> exactly one refill boundary
            mt, buf, cur, u = model._draw_block(cfg, mt, buf, cur)
            outs.append(u)
        return jnp.stack(outs), cur

    us, cur = draws(jnp.asarray(mt0), jnp.asarray(buf0), jnp.int32(cur0))
    us = np.asarray(us)
    # 9 blocks fit in one twist (9*64=576 <= 624); blocks 10.. come from the
    # second twist starting at row 0
    assert int(cur) == (12 - 9) * 64
    # no block repeats (cursor advances)
    flat = us.reshape(12, -1)
    for i in range(12):
        for j in range(i + 1, 12):
            assert not (flat[i] == flat[j]).all(), (i, j)
    # values match the reference stream: block r rows [r*64, r*64+64)
    rp = [ref.Mt19937Py(5489 + k) for k in range(cfg.n_layers)]
    stream = np.array([[g.next_u32() for g in rp] for _ in range(624)], dtype=np.uint32)
    expect0 = (stream[:64] >> 8).astype(np.float32) / (1 << 24)
    assert (us[0] == expect0).all()


def test_scan_sweeps_equals_sequential_calls():
    w = workload.build_torus_workload(4, 4, 8, sweeps_per_call=3, seed=5)
    cfg3 = w.cfg
    cfg1 = model.ModelConfig(n_base=cfg3.n_base, n_layers=cfg3.n_layers,
                             max_degree=cfg3.max_degree, n_colors=cfg3.n_colors,
                             sweeps_per_call=1)
    masks = workload.coalesced_masks(w)
    mt, buf, cur = workload.fresh_rng(cfg3)
    args = (jnp.asarray(w.h), jnp.asarray(w.nbr_idx), jnp.asarray(w.nbr_j),
            jnp.asarray(masks), jnp.float32(0.9), jnp.float32(w.jtau))

    s3, mt3, buf3, cur3, flips3, e3 = jax.jit(model.make_sweep_coalesced(cfg3))(
        jnp.asarray(w.s0), jnp.asarray(mt), jnp.asarray(buf), jnp.int32(cur), *args)

    sweep1 = jax.jit(model.make_sweep_coalesced(cfg1))
    s, m_, b_, c_ = jnp.asarray(w.s0), jnp.asarray(mt), jnp.asarray(buf), jnp.int32(cur)
    total = 0.0
    for _ in range(3):
        s, m_, b_, c_, f, e = sweep1(s, m_, b_, c_, *args)
        total += float(f)
    assert (np.asarray(s) == np.asarray(s3)).all()
    assert total == float(flips3)
    assert abs(float(e) - float(e3)) < 1e-4


def test_energy_formulas_match_oracle():
    w = workload.build_torus_workload(4, 4, 8, sweeps_per_call=1, seed=9)
    e_ref = ref.total_energy_ref(w.s0, w.h, w.nbr_idx, w.nbr_j, w.jtau)
    e_coal = float(model.energy_coalesced(
        jnp.asarray(w.s0), jnp.asarray(w.h), jnp.asarray(w.nbr_idx),
        jnp.asarray(w.nbr_j), jnp.float32(w.jtau)))
    sf, hf, fidx, fj, _ = workload.to_flat(w)
    e_flat = float(model.energy_flat(jnp.asarray(sf), jnp.asarray(hf),
                                     jnp.asarray(fidx), jnp.asarray(fj)))
    assert abs(e_coal - e_ref) < 1e-3
    assert abs(e_flat - e_ref) < 1e-3


@pytest.mark.parametrize("variant", ["b1_naive", "b2_coalesced"])
def test_lowering_produces_clean_hlo(variant):
    cfg = model.ModelConfig(n_base=16, n_layers=8, max_degree=4, n_colors=2, sweeps_per_call=2)
    hlo, sig = aot.lower_variant(cfg, variant)
    assert "custom-call" not in hlo, "artifact must be pure HLO (no Mosaic custom-calls)"
    assert "ENTRY" in hlo
    n_inputs = 10 if variant == "b2_coalesced" else 9
    assert len(sig) == n_inputs
    # scalar inputs have empty shapes
    assert sig[3]["shape"] == [] and sig[3]["dtype"] == "int32"


def test_lowering_rejects_unknown_variant():
    cfg = model.ModelConfig(n_base=16, n_layers=8, max_degree=4, n_colors=2, sweeps_per_call=1)
    with pytest.raises(ValueError):
        aot.lower_variant(cfg, "b3_imaginary")


def test_workload_masks_partition_spins():
    w = workload.build_torus_workload(6, 4, 8, sweeps_per_call=1, seed=2)
    masks = workload.coalesced_masks(w)
    assert masks.shape == (4, 24, 8)
    assert (masks.sum(axis=0) == 1.0).all()
    _, _, _, _, flat_masks = workload.to_flat(w)
    assert (flat_masks.sum(axis=0) == 1.0).all()
    # flat mask of phase p corresponds to coalesced mask of phase p
    for ph in range(4):
        flat_from_coal = masks[ph].T.reshape(-1)  # (L,N) flat layer-major
        assert (flat_from_coal == flat_masks[ph]).all()


def test_lcg_golden_values_shared_with_rust():
    rng = workload.Lcg(1)
    assert [rng.next_u64() for _ in range(4)] == [
        2088359638719790806, 5991960103029929709,
        13547870596056087544, 6385483684110717927]
