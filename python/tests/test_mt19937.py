"""MT19937 kernel correctness: golden vectors, CPython cross-check,
sequential oracle, Pallas kernel, and hypothesis sweeps over lanes/seeds."""

import random

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mt19937, ref

GOLDEN_5489 = [3499211612, 581869302, 3890346734, 3586334585, 545404204,
               4161255391, 3922919429, 949333985, 2715962298, 1323567403]


def test_python_ref_matches_golden_vector():
    r = ref.Mt19937Py(5489)
    assert [r.next_u32() for _ in range(10)] == GOLDEN_5489


@pytest.mark.parametrize("seed", [0, 1, 5489, 0xDEADBEEF, 2**32 - 1])
def test_python_ref_matches_cpython_c_implementation(seed):
    """Validates twist + temper against CPython's C MT19937 via setstate."""
    r = ref.Mt19937Py(seed)
    rr = random.Random()
    rr.setstate(r.cpython_state())
    assert [r.next_u32() for _ in range(1500)] == [rr.getrandbits(32) for _ in range(1500)]


def test_vectorized_twist_matches_sequential_oracle():
    st0 = mt19937.init_state([5489, 1, 42, 999])
    new, block = mt19937.twist(jnp.asarray(st0))
    new_r, block_r = ref.mt19937_ref_block(jnp.asarray(st0))
    assert (np.asarray(new) == np.asarray(new_r)).all()
    assert (np.asarray(block) == np.asarray(block_r)).all()


def test_lane_zero_equals_scalar_stream():
    st0 = mt19937.init_state([5489, 7])
    _, block = mt19937.twist(jnp.asarray(st0))
    rp = ref.Mt19937Py(5489)
    assert np.asarray(block)[:, 0].tolist() == [rp.next_u32() for _ in range(624)]
    rp7 = ref.Mt19937Py(7)
    assert np.asarray(block)[:, 1].tolist() == [rp7.next_u32() for _ in range(624)]


def test_pallas_kernel_matches_jnp_twist():
    st0 = mt19937.init_state(list(range(100, 108)))
    new_j, block_j = mt19937.twist(jnp.asarray(st0))
    new_p, block_p = mt19937.twist_pallas(jnp.asarray(st0))
    assert (np.asarray(new_p) == np.asarray(new_j)).all()
    assert (np.asarray(block_p) == np.asarray(block_j)).all()


def test_second_twist_continues_stream():
    st0 = mt19937.init_state([5489])
    st1, b1 = mt19937.twist(jnp.asarray(st0))
    _, b2 = mt19937.twist(st1)
    rp = ref.Mt19937Py(5489)
    expect = [rp.next_u32() for _ in range(1248)]
    got = np.concatenate([np.asarray(b1)[:, 0], np.asarray(b2)[:, 0]]).tolist()
    assert got == expect


@settings(max_examples=20, deadline=None)
@given(
    seeds=st.lists(st.integers(min_value=0, max_value=2**32 - 1), min_size=1, max_size=9),
)
def test_property_lanes_independent_of_width(seeds):
    """Lane k of a W-lane generator equals the 1-lane generator of seed k
    regardless of how many other lanes are interlaced."""
    st_w = mt19937.init_state(seeds)
    _, block_w = mt19937.twist(jnp.asarray(st_w))
    for k, s in enumerate(seeds):
        st_1 = mt19937.init_state([s])
        _, block_1 = mt19937.twist(jnp.asarray(st_1))
        assert (np.asarray(block_w)[:, k] == np.asarray(block_1)[:, 0]).all()


def test_uniforms_have_24_bit_resolution_and_unit_range():
    st0 = mt19937.init_state([5489, 123])
    _, block = mt19937.twist(jnp.asarray(st0))
    u = np.asarray(mt19937.uniforms_from_bits(block))
    assert (u >= 0.0).all() and (u < 1.0).all()
    # every value sits on the 2^-24 grid
    assert (u * (1 << 24) == np.floor(u * (1 << 24))).all()


def test_uniform_mean_and_variance():
    st0 = mt19937.init_state(list(range(16)))
    _, block = mt19937.twist(jnp.asarray(st0))
    u = np.asarray(mt19937.uniforms_from_bits(block)).ravel()
    assert abs(u.mean() - 0.5) < 0.01
    assert abs(u.var() - 1.0 / 12.0) < 0.005
